"""Code generation + stream machine: functional equivalence, cycle
agreement with the analytic model, packing legality.  End-to-end cases go
through the unified driver (repro.compile); unit-level model tests keep
using the thin codegen wrappers directly."""
import numpy as np
import pytest

import repro
from repro.core import codegen, library, scheduler, stream, targets
from repro.core.codegen import StreamTooLarge, xfer_chunks

from conftest import random_inputs

CASES = [
    ("example", lambda: library.gemm(8, 16, 12, in_dtype="i16")),
    ("example", lambda: library.elementwise("ADD", 25, "i16")),
    ("hvx", lambda: library.gemm(8, 16, 12, in_dtype="u8")),
    ("hvx", lambda: library.gemm(8, 8, 8, heads=3, in_dtype="u8")),
    ("hvx", lambda: library.conv2d(1, 12, 12, 3, 8, 3, 3, 2, name="cc")),
    ("hvx", lambda: library.relu(37, "i32")),
    ("dnnweaver", lambda: library.gemm(8, 16, 12, in_dtype="u8")),
    ("dnnweaver", lambda: library.conv2d(1, 12, 12, 3, 8, 3, 3, 2, name="cd")),
    ("dnnweaver", lambda: library.elementwise("MUL", 64, "i32")),
]


@pytest.mark.parametrize("target,build", CASES)
def test_stream_matches_oracle(target, build, rng):
    cdlt = build()
    art = repro.compile(cdlt, target)
    ins = random_inputs(cdlt, rng, lo=0, hi=5)
    res = art.run(ins)
    want = cdlt.oracle(ins)
    for k in want:
        np.testing.assert_array_equal(res.outputs[k], want[k])
    assert art.verify(ins)


@pytest.mark.parametrize("target,build", CASES)
def test_stream_cycles_agree_with_analytic(target, build, rng):
    """cost.py is mnemonic-faithful: serial stream cycles match the
    analytic model (exactly on unclamped tiles, <=2%% on clamped convs)."""
    art = repro.compile(build(), target)
    res = art.run(random_inputs(build(), rng, 0, 3), pack=False)
    analytic = art.cycles(pack=False)
    assert abs(res.serial_cycles - analytic) / max(analytic, 1) < 0.02


def test_packing_preserves_program_order_dependencies():
    """No packet may contain two mnemonics with a data hazard, and packets
    respect original order for dependent pairs."""
    prog = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"),
                         "hvx").program
    packets = stream.pack_stream(prog)
    ms = prog.mnemonics
    pos = {}
    for pi, packet in enumerate(packets):
        for k in packet:
            pos[k] = pi
        for a in packet:
            for b in packet:
                if a < b:
                    from repro.core.stream import _conflict
                    assert not _conflict(ms[a], ms[b]), (a, b)
    # dependent pairs must stay ordered across packets
    for i in range(len(ms)):
        for j in range(i + 1, min(i + 20, len(ms))):
            from repro.core.stream import _conflict
            if _conflict(ms[i], ms[j]):
                assert pos[i] <= pos[j]


def test_packing_reduces_cycles_on_vliw():
    art = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx")
    res = art.run({
        "A": np.ones((8, 12), np.uint8), "B": np.ones((12, 16), np.uint8)})
    assert res.packed_cycles < res.serial_cycles
    assert res.packing_speedup <= art.acg.issue_slots


def test_packing_noop_on_single_issue():
    art = repro.compile(library.gemm(8, 8, 8, in_dtype="u8"),
                        "dnnweaver")  # issue_slots = 1
    res = art.run({
        "A": np.ones((8, 8), np.uint8), "B": np.ones((8, 8), np.uint8)})
    assert res.packed_cycles == res.serial_cycles


def test_all_mnemonics_encode(rng):
    art = repro.compile(library.conv2d(1, 10, 10, 3, 4, 3, 3, 1, name="ce"),
                        "hvx")
    for m in art.program.mnemonics:
        w = m.encode()
        assert 0 <= w < (1 << m.mdef.bits)
    assert art.program.bytes > 0


def test_stream_size_guard():
    # via the legacy wrapper...
    acg = targets.get_target("hvx")
    sched = scheduler.schedule(library.gemm(64, 64, 64, in_dtype="u8"), acg)
    with pytest.raises(StreamTooLarge):
        codegen.generate(sched, acg, max_mnemonics=10)
    # ...and via the unified options (lazy codegen fires on .program)
    art = repro.compile(library.gemm(64, 64, 64, in_dtype="u8"), "hvx",
                        repro.CompileOptions(max_mnemonics=10), cache=False)
    with pytest.raises(StreamTooLarge):
        art.program


def test_xfer_chunks_model():
    # row wider than edge: split per row
    n, g, per = xfer_chunks(rows=4, row_bits=1000, coalesce=1, bandwidth=256)
    assert (n, g, per) == (16, 1, 4)
    # coalescing bounded by bandwidth
    n, g, per = xfer_chunks(rows=8, row_bits=64, coalesce=4, bandwidth=256)
    assert (n, g, per) == (2, 4, 1)
    # no unroll: one row per op (Fig 8b)
    n, g, per = xfer_chunks(rows=8, row_bits=64, coalesce=1, bandwidth=256)
    assert (n, g, per) == (8, 1, 1)


def test_loop_overhead_emitted_only_when_configured():
    # hvx: loop_overhead = 1; dnnweaver: hardware loops, 0
    for target, expect in (("hvx", True), ("dnnweaver", False)):
        prog = repro.compile(library.gemm(8, 8, 8, in_dtype="u8"),
                             target).program
        has_loopi = any(m.mdef.name == "LOOPI" for m in prog.mnemonics)
        assert has_loopi == expect


def test_fig12_optimization_stack_monotone(rng):
    """vanilla >= +vectorize >= +vectorize+unroll (analytic cycles), and
    every stage stays functionally correct — the Fig-12 protocol."""
    cdlt = library.gemm(16, 32, 16, in_dtype="u8")
    ins = random_inputs(cdlt, rng, 0, 4)
    want = cdlt.oracle(ins)
    cycles = {}
    big = 2_000_000
    for tag, opts in [
        ("vanilla", repro.CompileOptions(vectorize=False, unroll=False,
                                         pack=False, max_mnemonics=big)),
        ("vec", repro.CompileOptions(vectorize=True, unroll=False,
                                     pack=False, max_mnemonics=big)),
        ("vec+unroll", repro.CompileOptions(vectorize=True, unroll=True,
                                            pack=False, max_mnemonics=big)),
    ]:
        art = repro.compile(cdlt, "hvx", opts)
        res = art.run(ins)
        np.testing.assert_array_equal(res.outputs["C"], want["C"])
        cycles[tag] = res.serial_cycles
    assert cycles["vanilla"] > cycles["vec"]
    assert cycles["vec"] >= cycles["vec+unroll"]
