"""Covenant scheduling pipeline + Algorithm-1 property tests (hypothesis)."""
import math

import numpy as np
import pytest

pytest.importorskip("hypothesis")  # container may lack it; gate, don't fail
from hypothesis import given, settings, strategies as st

from repro.core import library, scheduler, targets
from repro.core.scheduler import (enumerate_tilings, plan_operands,
                                  validate_tiling)


def _prepped(cdlt, acg, vectorize=True):
    c = cdlt.clone()
    scheduler.place_operands(c, acg)
    scheduler.map_compute(c, acg, vectorize=vectorize)
    plans = plan_operands(c, acg)
    return c, plans


# ---------------------------------------------------------------------------
# unit behaviour
# ---------------------------------------------------------------------------


def test_place_operands_uses_home():
    acg = targets.example_acg()
    c, _ = _prepped(library.gemm(4, 4, 4, in_dtype="i16"), acg)
    assert all(s.loc == "DRAM" for s in c.surrogates.values()
               if s.kind in ("inp", "out"))


def test_map_compute_picks_widest():
    acg = targets.example_acg()
    c, _ = _prepped(library.elementwise("ADD", 8, "i16"), acg)
    (_, op), = c.computes()
    assert op.loc == "VECTOR"


def test_map_compute_baseline_picks_narrowest():
    acg = targets.example_acg()
    c, _ = _prepped(library.elementwise("ADD", 8, "i16"), acg, vectorize=False)
    (_, op), = c.computes()
    assert op.loc == "SCALAR"


def test_matmul_family_aliasing():
    # a MAC codelet schedules onto DNNWeaver's systolic GEMM capability
    acg = targets.dnnweaver_acg()
    c, _ = _prepped(library.gemm(4, 4, 4), acg)
    (_, op), = c.computes()
    assert op.loc == "SYSTOLIC"
    assert op.cap_obj.geometry == (1, 64, 64)


def test_unsupported_capability_raises():
    acg = targets.example_acg()
    c = library.elementwise("ADD", 8, "f32")  # example ACG is integer-only
    with pytest.raises(ValueError, match="no ACG node"):
        scheduler.schedule(c, acg)


def test_operand_ports_respected():
    acg = targets.dnnweaver_acg()
    c, plans = _prepped(library.gemm(4, 4, 4), acg)
    staging = {p.surrogate: p.staging for p in plans}
    assert staging["A"] == "IBUF"
    assert staging["B"] == "WBUF"
    assert staging["C"] == "OBUF"


def test_schedule_is_nondestructive():
    acg = targets.example_acg()
    c = library.gemm(4, 4, 4, in_dtype="i16")
    before = str(c)
    scheduler.schedule(c, acg)
    assert str(c) == before  # schedule works on a clone


def test_split_loops_rewrites_refs():
    acg = targets.example_acg()
    c, plans = _prepped(library.gemm(8, 8, 8, in_dtype="i16"), acg)
    scheduler.split_loops(c, {"m": 4, "n": 8, "k": 8})
    tile_loops = [l for l in c.loops() if l.role == "tile"]
    assert [l.var for l in tile_loops] == ["m"]
    assert tile_loops[0].stride == 4
    (_, op), = c.computes()
    # m index must now be m + m_i
    vars_ = op.out.idx[0].vars()
    assert vars_ == {"m", "m_i"}


# ---------------------------------------------------------------------------
# Algorithm 1 — property-based validation
# ---------------------------------------------------------------------------


@st.composite
def gemm_dims(draw):
    m = draw(st.integers(1, 24))
    n = draw(st.integers(1, 24))
    k = draw(st.integers(1, 24))
    return m, n, k


@given(gemm_dims())
@settings(max_examples=25, deadline=None)
def test_valid_tilings_fit_and_align(dims):
    """Every tiling Algorithm 1 accepts satisfies its own constraints."""
    m, n, k = dims
    acg = targets.example_acg()
    c, plans = _prepped(library.gemm(m, n, k, in_dtype="i16"), acg)
    tilings = enumerate_tilings(c, acg, plans, max_candidates=50)
    for t in tilings:
        # recompute the constraint by hand
        from repro.core.scheduler import _tile_footprints
        fps = _tile_footprints(c, plans, t)
        storage = {mm.name: 0 for mm in acg.memory_nodes()}
        for p in plans:
            s = c.surrogates[p.surrogate]
            bits = math.prod(fps[p.surrogate]) * s.dtype.bits
            for edge, charge in p.hops(acg):
                assert bits % acg.memory(edge.src).data_width == 0
                storage[charge] += bits
                mem = acg.memory(charge)
                if not mem.offchip:
                    assert storage[charge] <= mem.capacity_bits


@given(gemm_dims())
@settings(max_examples=25, deadline=None)
def test_full_extent_tiling_judged_consistently(dims):
    """validate_tiling is deterministic and consistent with enumerate."""
    m, n, k = dims
    acg = targets.example_acg()
    c, plans = _prepped(library.gemm(m, n, k, in_dtype="i16"), acg)
    full = {l.var: l.trips for l in c.loops()}
    v1 = validate_tiling(c, acg, plans, full)
    v2 = validate_tiling(c, acg, plans, full)
    assert v1 == v2
    if v1:
        assert any(t == full for t in
                   enumerate_tilings(c, acg, plans, max_candidates=10**6))


@given(st.integers(2, 64), st.integers(2, 64))
@settings(max_examples=20, deadline=None)
def test_oversized_tiles_rejected(m, n):
    """A tile bigger than every on-chip memory must be rejected."""
    acg = targets.example_acg()  # GSP = 28,672 B
    k = 512
    c, plans = _prepped(library.gemm(m, n, k, in_dtype="i16"), acg)
    full = {l.var: l.trips for l in c.loops()}
    bits = (m * k + k * n + m * n) * 16
    if bits > acg.memory("GSP").capacity_bits:
        assert not validate_tiling(c, acg, plans, full)


@given(gemm_dims())
@settings(max_examples=15, deadline=None)
def test_schedule_always_produces_valid_tiling(dims):
    """End-to-end: the chosen tiling divides loop ranges and fits."""
    m, n, k = dims
    acg = targets.example_acg()
    s = scheduler.schedule(library.gemm(m, n, k, in_dtype="i16"), acg)
    assert s.tiling
    base = library.gemm(m, n, k, in_dtype="i16")
    for l in base.loops():
        assert l.trips % s.tiling[l.var] == 0


def test_padding_fallback_for_odd_sizes():
    """25 i16 elements can never align to 32-bit data_width: §4 padding."""
    acg = targets.example_acg()
    s = scheduler.schedule(library.elementwise("ADD", 25, "i16"), acg)
    assert any("zero-padded" in n for n in s.schedule_notes)
