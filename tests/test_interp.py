"""Functional correctness: scheduled Codelets == numpy oracles.

Covers the paper's full Table-2 benchmark set (reduced dims where the
original layer would take minutes under the python interpreter — the
*structure* of each layer is preserved) x both evaluation targets.
"""
import numpy as np
import pytest

from repro.core import interp, library, scheduler, targets
from repro.core.scheduler import ScheduleConfig

from conftest import random_inputs

# reduced-but-structure-preserving variants of Table 2
REDUCED_LAYERS = {
    "BERT-GEMM1": lambda: library.gemm(24, 64, 32, name="bert_gemm1_r"),
    "BERT-ATN1": lambda: library.gemm(24, 16, 32, heads=4, name="bert_atn1_r"),
    "BERT-ATN2": lambda: library.gemm(24, 24, 16, heads=4, name="bert_atn2_r"),
    "DLRM-FC1": lambda: library.fc(45, 23, name="dlrm_fc1_r"),
    "DLRM-FC4": lambda: library.fc(32, 1, name="dlrm_fc4_r"),
    "Incep-CONV1": lambda: library.conv2d(1, 19, 19, 3, 8, 3, 3, 2, name="ic1r"),
    "MbNet-CONV2": lambda: library.conv2d(1, 14, 14, 4, 8, 3, 3, 1, name="mc2r"),
    "ResNet-CONV1": lambda: library.conv2d(1, 18, 18, 3, 8, 7, 7, 2, name="rc1r"),
}


@pytest.mark.parametrize("target", ["hvx", "dnnweaver"])
@pytest.mark.parametrize("layer", sorted(REDUCED_LAYERS))
def test_paper_layers_match_oracle(target, layer, rng):
    acg = targets.get_target(target)
    cdlt = REDUCED_LAYERS[layer]()
    sched = scheduler.schedule(cdlt, acg)
    ins = random_inputs(cdlt, rng, lo=0, hi=5)  # u8 inputs like the paper
    got = interp.run(sched, acg, ins)
    want = cdlt.oracle(ins)
    for k in want:
        np.testing.assert_array_equal(got[k], want[k], err_msg=f"{layer}@{target}:{k}")


@pytest.mark.parametrize("target", ["example", "hvx", "dnnweaver"])
def test_unoptimized_schedule_also_correct(target, rng):
    """The Fig-12 baseline (no vectorize/unroll) is functionally identical."""
    acg = targets.get_target(target)
    dt = "i16" if target == "example" else "u8"
    cdlt = library.gemm(6, 10, 8, in_dtype=dt)
    cfg = ScheduleConfig(vectorize=False, unroll=False, pack=False)
    sched = scheduler.schedule(cdlt, acg, cfg)
    ins = random_inputs(cdlt, rng, lo=0, hi=4)
    got = interp.run(sched, acg, ins)
    want = cdlt.oracle(ins)
    np.testing.assert_array_equal(got["C"], want["C"])


@pytest.mark.parametrize("n", [1, 4, 25, 37, 64])
def test_elementwise_sizes(n, rng):
    """Fig-9 territory: lane remainders across sizes."""
    acg = targets.get_target("hvx")
    for opname in ("ADD", "MUL", "MAX"):
        cdlt = library.elementwise(opname, n, "i32")
        sched = scheduler.schedule(cdlt, acg)
        ins = random_inputs(cdlt, rng, lo=-9, hi=9)
        got = interp.run(sched, acg, ins)
        want = cdlt.oracle(ins)
        np.testing.assert_array_equal(got["c"], want["c"])


def test_unary_nonlinearities(rng):
    acg = targets.get_target("dnnweaver")
    for opname in ("RELU", "SIGMOID", "TANH"):
        cdlt = library.elementwise(opname, 40, "i32", arity=1)
        sched = scheduler.schedule(cdlt, acg)
        ins = random_inputs(cdlt, rng, lo=-3, hi=4)
        got = interp.run(sched, acg, ins)
        want = cdlt.oracle(ins)
        np.testing.assert_array_equal(got["c"], want["c"])


def test_strided_conv_structure(rng):
    """stride > kernel: disjoint patches (ResNet-CONV2 style, stride 4)."""
    acg = targets.get_target("dnnweaver")
    cdlt = library.conv2d(1, 16, 16, 4, 8, 3, 3, 4, name="rc2r")
    sched = scheduler.schedule(cdlt, acg)
    ins = random_inputs(cdlt, rng, lo=0, hi=4)
    got = interp.run(sched, acg, ins)
    want = cdlt.oracle(ins)
    np.testing.assert_array_equal(got["O"], want["O"])


def test_paper_table2_full_set_schedules():
    """All 17 full-size Table-2 layers schedule on both targets (no
    execution — the python interpreter would be too slow; functional
    equivalence is covered by the reduced variants above)."""
    for spec in library.PAPER_LAYERS:
        for target in ("hvx", "dnnweaver"):
            acg = targets.get_target(target)
            sched = scheduler.schedule(spec.build(), acg)
            assert sched.tiling, f"{spec.key}@{target}"
