"""Property tests over the full Covenant pipeline (hypothesis): random
GEMM/elementwise problems must schedule, generate, execute and agree with
the numpy oracle on every target — the paper's retargetability claim as an
invariant."""
import numpy as np
import pytest

pytest.importorskip("hypothesis")  # container may lack it; gate, don't fail
from hypothesis import given, settings, strategies as st

from repro.core import codegen, interp, library, scheduler, stream, targets


@st.composite
def gemm_problem(draw):
    m = draw(st.integers(1, 16))
    n = draw(st.integers(1, 16))
    k = draw(st.integers(1, 16))
    heads = draw(st.sampled_from([1, 1, 2]))
    return m, n, k, heads


@given(gemm_problem(), st.sampled_from(["hvx", "dnnweaver"]))
@settings(max_examples=12, deadline=None)
def test_random_gemm_end_to_end(prob, target):
    m, n, k, heads = prob
    acg = targets.get_target(target)
    cdlt = library.gemm(m, n, k, heads=heads, in_dtype="u8")
    sched = scheduler.schedule(cdlt, acg)
    rng = np.random.default_rng(m * 131 + n * 17 + k)
    hd = [heads] if heads > 1 else []
    ins = {"A": rng.integers(0, 5, hd + [m, k]).astype(np.uint8),
           "B": rng.integers(0, 5, hd + [k, n]).astype(np.uint8)}
    want = cdlt.oracle(ins)["C"]
    # functional interpreter
    got_i = interp.run(sched, acg, ins)["C"]
    np.testing.assert_array_equal(got_i, want)
    # executable mnemonic stream (skip if too large to unroll)
    try:
        prog = codegen.generate(sched, acg, max_mnemonics=100_000)
    except codegen.StreamTooLarge:
        return
    res = stream.run_stream(prog, ins)
    np.testing.assert_array_equal(res.outputs["C"], want)
    assert res.packed_cycles <= res.serial_cycles


@given(st.integers(1, 80), st.sampled_from(["ADD", "MUL", "MAX"]),
       st.sampled_from(["hvx", "dnnweaver"]))
@settings(max_examples=15, deadline=None)
def test_random_elementwise_end_to_end(n, opname, target):
    acg = targets.get_target(target)
    cdlt = library.elementwise(opname, n, "i32")
    sched = scheduler.schedule(cdlt, acg)
    rng = np.random.default_rng(n)
    ins = {"a": rng.integers(-50, 50, n).astype(np.int32),
           "b": rng.integers(-50, 50, n).astype(np.int32)}
    want = cdlt.oracle(ins)["c"]
    got = interp.run(sched, acg, ins)["c"]
    np.testing.assert_array_equal(got, want)
    prog = codegen.generate(sched, acg)
    res = stream.run_stream(prog, ins)
    np.testing.assert_array_equal(res.outputs["c"], want)


@given(st.integers(2, 12), st.integers(2, 12), st.integers(2, 12))
@settings(max_examples=10, deadline=None)
def test_cost_monotone_in_problem_size(m, n, k):
    """Doubling the k (reduction) dim never decreases analytic cycles."""
    from repro.core import cost
    acg = targets.get_target("hvx")
    c1 = cost.cost(scheduler.schedule(library.gemm(m, n, k, in_dtype="u8"),
                                      acg), acg).cycles
    c2 = cost.cost(scheduler.schedule(library.gemm(m, n, 2 * k,
                                                   in_dtype="u8"), acg),
                   acg).cycles
    assert c2 >= c1 * 0.95  # tiling choice may shift slightly; never halve
