"""Elastic scaling: a checkpoint written under one mesh restores onto a
different mesh (and onto a single device) with identical values — the
resume path a real fleet uses after losing/gaining slices."""
import os
import subprocess
import sys

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))

_SCRIPT = r"""
import os, sys
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import checkpoint as ckpt
from repro import configs
from repro.models import get_model
from repro.runtime import sharding as shard_rules

ckpt_dir = sys.argv[1]
cfg = configs.get_config("qwen3-0.6b", smoke=True)
model = get_model(cfg)
params = model.init_params(jax.random.PRNGKey(0))

# save under an 8-device (2,4) mesh
mesh_a = jax.make_mesh((2, 4), ("data", "model"))
sh_a = shard_rules.shardings(params, mesh_a)
placed = jax.tree.map(jax.device_put, params, sh_a)
ckpt.save_checkpoint(ckpt_dir, 7, placed)

# restore onto a DIFFERENT mesh (4,2) — elastic reshard on load
mesh_b = jax.make_mesh((4, 2), ("data", "model"))
sh_b = shard_rules.shardings(params, mesh_b)
restored, step, _ = ckpt.restore_sharded(ckpt_dir, params, sh_b)
assert step == 7
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# and onto a single device
one = NamedSharding(jax.make_mesh((1,), ("x",)), P())
sh_c = jax.tree.map(lambda _: one, params)
restored2, step2, _ = ckpt.restore_sharded(ckpt_dir, params, sh_c)
for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(restored2)):
    np.testing.assert_array_equal(np.asarray(a), np.asarray(b))

# the restored-under-B params give identical losses
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab)
batch = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
l0 = float(model.loss_fn(params, batch))
l1 = float(model.loss_fn(jax.device_get(restored), batch))
assert abs(l0 - l1) < 1e-5, (l0, l1)
print("ELASTIC_OK")
"""


def test_cross_mesh_restore(tmp_path):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _SCRIPT, str(tmp_path)],
                       capture_output=True, text=True, env=env, cwd=ROOT,
                       timeout=600)
    assert "ELASTIC_OK" in r.stdout, (r.stdout[-1000:], r.stderr[-3000:])
