"""Substrate tests: data determinism, optimizer, checkpointing (atomic,
keep-k, elastic), sharding rules, fault tolerance, grad accumulation,
compression; multi-device collectives run in a subprocess with 8 fake
CPU devices (so this process keeps the single real device)."""
import os
import subprocess
import sys
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro import configs
from repro.data import SyntheticLM
from repro.models import get_model
from repro.optim import adamw, cosine_schedule, global_norm, int8_compressed
from repro.optim.compression import compress, decompress
from repro.runtime import make_train_step, spec_for, train_loop
from repro.runtime.fault_tolerance import StragglerMonitor


# ---------------------------------------------------------------------------
# data
# ---------------------------------------------------------------------------


def test_data_deterministic_and_host_sharded():
    a = SyntheticLM(vocab=100, seq_len=32, global_batch=8, seed=3)
    b = SyntheticLM(vocab=100, seq_len=32, global_batch=8, seed=3)
    np.testing.assert_array_equal(a.batch(5)["tokens"], b.batch(5)["tokens"])
    assert not np.array_equal(a.batch(5)["tokens"], a.batch(6)["tokens"])
    # two hosts partition the global batch exactly
    h0 = SyntheticLM(vocab=100, seq_len=32, global_batch=8, seed=3,
                     n_hosts=2, host_id=0)
    h1 = SyntheticLM(vocab=100, seq_len=32, global_batch=8, seed=3,
                     n_hosts=2, host_id=1)
    full = a.batch(2)["tokens"]
    np.testing.assert_array_equal(
        np.concatenate([h0.batch(2)["tokens"], h1.batch(2)["tokens"]]), full)


def test_data_packing_structure():
    d = SyntheticLM(vocab=64, seq_len=64, global_batch=4, seed=0)
    b = d.batch(0)
    assert b["tokens"].shape == (4, 64) and b["targets"].shape == (4, 64)
    # targets are tokens shifted by one within the packed stream
    seq = d._sequence(0, 0)
    np.testing.assert_array_equal(b["tokens"][0], seq[:-1])
    np.testing.assert_array_equal(b["targets"][0], seq[1:])
    # EOS positions are masked out of the loss
    assert np.all(b["weights"][b["targets"] == d.eos] == 0.0)
    assert b["weights"].sum() > 0
    # learnability itself is asserted end-to-end by
    # test_loop_trains_checkpoints_resumes (loss decreases).


# ---------------------------------------------------------------------------
# optimizer
# ---------------------------------------------------------------------------


def test_adamw_descends_quadratic():
    opt = adamw(0.1, weight_decay=0.0)
    params = {"w": jnp.asarray([3.0, -2.0])}
    state = opt.init(params)
    loss = lambda p: jnp.sum(p["w"] ** 2)
    for _ in range(100):
        g = jax.grad(loss)(params)
        params, state, _ = opt.update(g, state, params)
    assert float(loss(params)) < 1e-2


def test_cosine_schedule_shape():
    lr = cosine_schedule(1.0, warmup=10, total=100, floor=0.1)
    assert float(lr(0)) == 0.0
    assert abs(float(lr(10)) - 1.0) < 1e-6
    assert float(lr(100)) == pytest.approx(0.1, abs=1e-3)
    assert float(lr(55)) < float(lr(20))


def test_grad_clipping():
    opt = adamw(0.1, max_grad_norm=1.0)
    params = {"w": jnp.ones((4,))}
    state = opt.init(params)
    big = {"w": jnp.full((4,), 1e6)}
    _, _, m = opt.update(big, state, params)
    assert float(m["grad_norm"]) > 1e5  # reported pre-clip norm


def test_compression_error_feedback_reduces_bias():
    rng = np.random.default_rng(0)
    g = jnp.asarray(rng.standard_normal(512) * 1e-3)
    q, s = compress(g)
    assert q.dtype == jnp.int8
    deq = decompress(q, s)
    assert float(jnp.max(jnp.abs(deq - g))) <= float(s) / 2 + 1e-9
    # accumulated error with feedback ~ accumulated error of one step
    err = jnp.zeros_like(g)
    total_fb = jnp.zeros_like(g)
    for _ in range(16):
        corrected = g + err
        q, s = compress(corrected)
        deq = decompress(q, s)
        err = corrected - deq
        total_fb = total_fb + deq
    assert float(jnp.mean(jnp.abs(total_fb / 16 - g))) < \
        float(jnp.mean(jnp.abs(decompress(*compress(g)) - g)))


# ---------------------------------------------------------------------------
# checkpointing
# ---------------------------------------------------------------------------


def _tree():
    return {"a": jnp.arange(6, dtype=jnp.float32).reshape(2, 3),
            "b": {"c": jnp.ones((4,), jnp.bfloat16)}}


def test_checkpoint_roundtrip_and_keep_k():
    with tempfile.TemporaryDirectory() as d:
        t = _tree()
        for s in (1, 2, 3, 4, 5):
            ckpt.save_checkpoint(d, s, t, keep=2)
        assert ckpt.latest_step(d) == 5
        kept = sorted(os.listdir(d))
        assert kept == ["step_00000004", "step_00000005"]
        loaded, step, _ = ckpt.load_checkpoint(d, t)
        assert step == 5
        np.testing.assert_array_equal(loaded["a"], np.asarray(t["a"]))


def test_checkpoint_shape_mismatch_rejected():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_checkpoint(d, 1, _tree())
        bad = {"a": jnp.zeros((3, 3)), "b": {"c": jnp.ones((4,))}}
        with pytest.raises(ValueError, match="shape"):
            ckpt.load_checkpoint(d, bad)


def test_checkpoint_atomicity_no_partial_dirs():
    with tempfile.TemporaryDirectory() as d:
        ckpt.save_checkpoint(d, 7, _tree())
        # a stale tmp dir (crashed writer) must be invisible to latest_step
        os.makedirs(os.path.join(d, ".tmp_dead"), exist_ok=True)
        open(os.path.join(d, ".tmp_dead", "arrays.npz"), "w").close()
        assert ckpt.latest_step(d) == 7


# ---------------------------------------------------------------------------
# sharding rules
# ---------------------------------------------------------------------------


def test_sharding_rules_match_expected_axes():
    from jax.sharding import PartitionSpec as P
    cases = {
        ("embed/tokens", (256000, 12288)): P("model", "data"),
        ("layers/#0/attn/wq", (8, 12288, 12288)): P(None, "data", "model"),
        ("layers/#0/mlp/wi", (8, 12288, 33792)): P(None, "data", "model"),
        ("layers/#0/mlp/wo", (8, 33792, 12288)): P(None, "model", "data"),
        ("layers/#0/ffn/wi", (16, 64, 2048, 1408)): P(None, "model", "data",
                                                      None),
        ("layers/#0/ln1/scale", (8, 12288)): P(),
        ("layers/#0/mamba/in_proj", (64, 2560, 10640)): P(None, "data",
                                                          "model"),
    }
    for (path, shape), want in cases.items():
        got = spec_for(path, shape)
        assert got == want, (path, got, want)


# ---------------------------------------------------------------------------
# fault tolerance
# ---------------------------------------------------------------------------


@pytest.fixture(scope="module")
def tiny_setup():
    cfg = configs.get_config("qwen3-0.6b", smoke=True)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    opt = adamw(1e-3)
    opt_state = opt.init(params)
    step_fn = jax.jit(make_train_step(model.loss_fn, opt))
    data = SyntheticLM(vocab=cfg.vocab, seq_len=32, global_batch=4, seed=0)
    return model, params, opt_state, step_fn, data


def test_loop_trains_checkpoints_resumes(tiny_setup):
    model, params, opt_state, step_fn, data = tiny_setup
    with tempfile.TemporaryDirectory() as d:
        p, o, rep = train_loop(step_fn, params, opt_state,
                               lambda s: data.batch(s), steps=8, ckpt_dir=d,
                               ckpt_every=4, logger=lambda *a: None)
        assert rep.steps_run == 8 and rep.resumed_from is None
        p, o, rep2 = train_loop(step_fn, params, opt_state,
                                lambda s: data.batch(s), steps=12,
                                ckpt_dir=d, ckpt_every=4,
                                logger=lambda *a: None)
        assert rep2.resumed_from == 8 and rep2.steps_run == 4


def test_loop_rolls_back_on_nan(tiny_setup):
    model, params, opt_state, step_fn, data = tiny_setup
    with tempfile.TemporaryDirectory() as d:
        p, o, rep = train_loop(step_fn, params, opt_state,
                               lambda s: data.batch(s), steps=6, ckpt_dir=d,
                               ckpt_every=2, inject_nan_at=3,
                               logger=lambda *a: None)
        assert rep.rollbacks == 1
        assert all(np.isfinite(l) for l in rep.losses)


def test_loop_survives_process_failure(tiny_setup):
    """Injected crash mid-run; a fresh loop resumes from the checkpoint."""
    from repro.runtime.fault_tolerance import InjectedFailure
    model, params, opt_state, step_fn, data = tiny_setup
    with tempfile.TemporaryDirectory() as d:
        with pytest.raises(InjectedFailure):
            train_loop(step_fn, params, opt_state, lambda s: data.batch(s),
                       steps=10, ckpt_dir=d, ckpt_every=2,
                       inject_failure_at=5, logger=lambda *a: None)
        p, o, rep = train_loop(step_fn, params, opt_state,
                               lambda s: data.batch(s), steps=10, ckpt_dir=d,
                               ckpt_every=2, logger=lambda *a: None)
        assert rep.resumed_from == 4  # last checkpoint before the crash
        assert rep.steps_run == 6


def test_straggler_monitor_flags_outliers():
    mon = StragglerMonitor(threshold=3.0)
    for i in range(20):
        assert not mon.observe(i, 0.1 + 0.001 * (i % 3))
    assert mon.observe(20, 1.5)
    assert mon.slow_steps and mon.slow_steps[0][0] == 20


def test_grad_accumulation_equivalence(tiny_setup):
    model, params, opt_state, _, data = tiny_setup
    opt = adamw(1e-3)
    s1 = jax.jit(make_train_step(model.loss_fn, opt, microbatches=1))
    s2 = jax.jit(make_train_step(model.loss_fn, opt, microbatches=4))
    b = {k: jnp.asarray(v) for k, v in data.batch(0).items()}
    p1, _, m1 = s1(params, opt.init(params), b)
    p2, _, m2 = s2(params, opt.init(params), b)
    assert float(m1["loss"]) == pytest.approx(float(m2["loss"]), rel=1e-5)
    diff = max(float(jnp.max(jnp.abs(a - b2)))
               for a, b2 in zip(jax.tree.leaves(p1), jax.tree.leaves(p2)))
    assert diff < 2e-5


# ---------------------------------------------------------------------------
# multi-device collectives (subprocess with 8 fake devices)
# ---------------------------------------------------------------------------

_COLLECTIVE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from repro.runtime.collectives import compressed_psum, sharded_decode_attention
from repro.kernels.ref import attention_ref

mesh = jax.make_mesh((2, 4), ("data", "model"))

# compressed psum ~= plain psum
g = {"w": jnp.asarray(np.random.default_rng(0).standard_normal((8, 16)),
                      jnp.float32)}
out = compressed_psum(g, mesh, axis="data")
want = jax.tree.map(lambda x: x * mesh.shape["data"], g)
err = float(jnp.max(jnp.abs(out["w"] - want["w"])))
rel = err / float(jnp.max(jnp.abs(want["w"])))
assert rel < 0.02, rel

# seq-sharded decode attention == dense reference
b, h, s, d = 2, 4, 64, 16
rng = np.random.default_rng(1)
q = jnp.asarray(rng.standard_normal((b, h, d)), jnp.float32)
k = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
v = jnp.asarray(rng.standard_normal((b, h, s, d)), jnp.float32)
lens = jnp.asarray([40, 64])
got = sharded_decode_attention(q, k, v, lens, mesh, seq_axis="model")
want = attention_ref(q[:, :, None], k, v, causal=False, kv_len=lens)[:, :, 0]
assert float(jnp.max(jnp.abs(got - want))) < 2e-3
print("COLLECTIVES_OK")
"""


def test_collectives_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    r = subprocess.run([sys.executable, "-c", _COLLECTIVE_SCRIPT],
                       capture_output=True, text=True, env=env,
                       cwd=os.path.dirname(os.path.dirname(__file__)) or ".")
    assert "COLLECTIVES_OK" in r.stdout, r.stderr[-2000:]
