"""Launch-layer tests: mesh factory, input specs, sharding assignments,
and a small-scale AOT lower+compile in a subprocess with fake devices
(a miniature of the real dry-run, fast enough for CI)."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.launch import specs as lspecs


def test_mesh_factory_shapes():
    # constructing the production meshes requires >= 512 devices, so here
    # we only check the factory's geometry logic via its source contract
    import inspect
    src = inspect.getsource(__import__("repro.launch.mesh",
                                       fromlist=["make_production_mesh"]))
    assert "(2, 16, 16)" in src and "(16, 16)" in src
    assert '("pod", "data", "model")' in src


def test_train_batch_specs_vlm_accounts_for_image_prefix():
    cfg = configs.get_config("paligemma-3b")
    from repro.models import get_model
    model = get_model(cfg)
    shape = configs.SHAPES["train_4k"]
    b = lspecs.train_batch_specs(cfg, shape, model)
    assert b["tokens"].shape == (256, 4096 - 256)
    assert b["patches"].shape == (256, 256, 1152)


def test_serve_specs_cache_shapes():
    cfg = configs.get_config("gemma3-12b")
    from repro.models import get_model
    model = get_model(cfg)
    shape = configs.SHAPES["decode_32k"]
    pre, tok, cache = lspecs.serve_specs(cfg, shape, model)
    assert tok.shape == (128,)
    # local layers: rolling window cache; global layers: full 32k
    local = cache["layers"][0]["k"]
    glob = cache["layers"][5]["k"]
    assert local.shape[3] == cfg.window
    assert glob.shape[3] == 32768


_MINI_DRYRUN = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax
from jax.sharding import Mesh
from repro.launch import specs
from repro.launch.mesh import use_mesh
from repro import configs
from repro.models.common import configure_activation_sharding

mesh = jax.make_mesh((2, 4), ("data", "model"))
# shrink shapes for speed: fabricate a small ShapeSpec
configs.SHAPES["mini_train"] = configs.ShapeSpec("mini_train", "train", 64, 8)
configs.SHAPES["mini_decode"] = configs.ShapeSpec("mini_decode", "decode",
                                                  64, 8)
ok = []
with use_mesh(mesh):
    configure_activation_sharding(("data",), "model", None, None)
    for arch, shape, kind in [
        ("qwen3-0.6b", "mini_train", "train"),
        ("whisper-base", "mini_train", "train"),
        ("qwen3-0.6b", "mini_decode", "decode"),
        ("mamba2-2.7b", "mini_decode", "decode"),
    ]:
        if kind == "train":
            fn, args, in_sh, out_sh = specs.train_cell(arch, shape, mesh,
                                                       microbatches=2)
            c = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                        donate_argnums=(0, 1)).lower(*args).compile()
        else:
            fn, args, in_sh, out_sh = specs.serve_cell(arch, shape, mesh,
                                                       "decode")
            c = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                        donate_argnums=(2,)).lower(*args).compile()
        assert c.cost_analysis() is not None
        ok.append(arch + ":" + kind)
    configure_activation_sharding(None, None, None, None)
print("MINI_DRYRUN_OK", ok)
"""


def test_mini_dryrun_compiles_multidevice():
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    root = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
    r = subprocess.run([sys.executable, "-c", _MINI_DRYRUN],
                       capture_output=True, text=True, env=env, cwd=root,
                       timeout=900)
    assert "MINI_DRYRUN_OK" in r.stdout, (r.stdout[-2000:], r.stderr[-4000:])


def test_layer_gemms_compile_through_driver():
    """The launch layer's Covenant bridge: per-block GEMMs of an arch
    compile via repro.compile (shared cache), and the report renders."""
    import repro
    from repro.launch import layers as llayers

    repro.clear_cache()
    cfg = configs.get_config("qwen3-0.6b", smoke=True)
    pairs = llayers.compile_layer_gemms(cfg, tokens=4)
    names = [g.name for g, _ in pairs]
    assert any("attn_qkv" in n for n in names)
    assert any("lm_head" in n for n in names)
    assert all(art.cycles() > 0 for _, art in pairs)
    # second compile of the same shapes is all cache hits
    before = repro.cache_stats()["misses"]
    llayers.compile_layer_gemms(cfg, tokens=4)
    assert repro.cache_stats()["misses"] == before
    report = llayers.layer_report(cfg, tokens=4)
    assert "block total" in report and cfg.name in report
    repro.clear_cache()


def test_layer_variant_report_spans_architecture_family():
    """The launch bridge sweeps derived accelerator variants by name in
    one heterogeneous compile_many batch."""
    import repro
    from repro.launch import layers as llayers

    repro.clear_cache()
    cfg = configs.get_config("qwen3-0.6b", smoke=True)
    report = llayers.variant_report(
        cfg, tokens=4, targets=["hvx", "hvx@edge.L2.VRF.bandwidth=512"])
    assert "hvx@edge.L2.VRF.bandwidth=512" in report
    assert "lm_head" in report
    repro.clear_cache()


def test_cache_spec_prefers_heads_then_seq():
    from jax.sharding import PartitionSpec as P

    class MeshStub:
        shape = {"data": 16, "model": 16}

    cfg = configs.get_config("command-r-plus-104b")
    # kv=8 cannot shard 16-way -> sequence over model
    spec = lspecs.cache_spec_for("layers/#0/k", (64, 128, 8, 32768, 128),
                                 cfg, MeshStub())
    assert spec == P(None, ("data",) if False else "data", None, "model",
                     None) or spec == P(None, "data", None, "model", None)
    # kv=16 (deepseek) -> heads over model
    cfg2 = configs.get_config("deepseek-moe-16b")
    spec2 = lspecs.cache_spec_for("layers/#0/k", (28, 128, 16, 32768, 128),
                                  cfg2, MeshStub())
    assert spec2 == P(None, "data", "model", None, None)
