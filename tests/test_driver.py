"""The unified compile driver (repro.compile): equivalence with the legacy
manual call chain, content-addressed caching, the pluggable pass pipeline,
and the per-ACG pass-override hook."""
import numpy as np
import pytest

import repro
from repro.core import codegen, cost, library, scheduler, stream, targets
from repro.core.codegen import StreamTooLarge
from repro.core.pipeline import Pipeline

from conftest import random_inputs

CASES = [
    ("hvx", lambda: library.gemm(8, 16, 12, in_dtype="u8")),
    ("hvx", lambda: library.elementwise("ADD", 64, "i32")),
    ("dnnweaver", lambda: library.gemm(8, 16, 12, in_dtype="u8")),
    ("dnnweaver", lambda: library.elementwise("ADD", 64, "i32")),
]


# ---------------------------------------------------------------------------
# (a) equivalence with the legacy manual pipeline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("target,build", CASES)
def test_compile_matches_legacy_chain(target, build, rng):
    """repro.compile() produces byte-identical mnemonic programs, equal
    analytic cycles, and equal stream outputs to the hand-stitched
    schedule -> generate -> run_stream -> cost chain."""
    cdlt = build()
    acg = targets.get_target(target)
    sched = scheduler.schedule(cdlt, acg)
    prog = codegen.generate(sched, acg)
    ins = random_inputs(cdlt, rng, 0, 5)
    legacy = stream.run_stream(prog, ins)
    legacy_cycles = cost.cost(sched, acg).cycles

    art = repro.compile(build(), target)
    assert [m.encode() for m in art.program.mnemonics] == \
        [m.encode() for m in prog.mnemonics]
    assert [str(m) for m in art.program.mnemonics] == \
        [str(m) for m in prog.mnemonics]
    assert art.cycles() == legacy_cycles
    res = art.run(ins)
    for k in legacy.outputs:
        np.testing.assert_array_equal(res.outputs[k], legacy.outputs[k])
    assert res.serial_cycles == legacy.serial_cycles
    assert art.verify(ins)


def test_layer_key_and_spec_resolution():
    """Paper-layer keys and LayerSpecs resolve to the same artifact as the
    built codelet (content addressing, not object identity)."""
    spec = library.PAPER_LAYERS[6]  # DLRM-FC1: small
    by_key = repro.compile(spec.key, "hvx")
    by_spec = repro.compile(spec, "hvx")
    by_cdlt = repro.compile(spec.build(), "hvx")
    assert by_key is by_spec is by_cdlt


# ---------------------------------------------------------------------------
# (b) content-addressed cache
# ---------------------------------------------------------------------------


def test_cache_hit_returns_same_artifact_without_rerunning():
    repro.clear_cache()
    a1 = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx")
    stages_run = list(a1.ctx.executed)
    a2 = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx")
    assert a2 is a1                       # same artifact object
    assert a1.ctx.executed == stages_run  # no pass re-ran
    stats = repro.cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1


def test_cache_misses_on_any_key_component():
    repro.clear_cache()
    base = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx")
    other_target = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"),
                                 "dnnweaver")
    other_opts = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx",
                               repro.CompileOptions(unroll=False))
    other_cdlt = repro.compile(library.gemm(8, 16, 13, in_dtype="u8"), "hvx")
    arts = {id(a) for a in (base, other_target, other_opts, other_cdlt)}
    assert len(arts) == 4
    assert repro.cache_stats()["misses"] == 4


def test_cache_bypass():
    repro.clear_cache()
    a1 = repro.compile(library.gemm(4, 8, 4, in_dtype="u8"), "hvx",
                       cache=False)
    a2 = repro.compile(library.gemm(4, 8, 4, in_dtype="u8"), "hvx",
                       cache=False)
    assert a1 is not a2
    assert repro.cache_stats()["size"] == 0


# ---------------------------------------------------------------------------
# (c) pluggable pipeline + per-ACG override hook
# ---------------------------------------------------------------------------


def test_acg_pass_hooks_execute():
    """A stage override and an extra pass installed on the ACG (BYOC-style)
    both actually run, in pipeline position."""
    acg = targets.get_target("hvx")
    ran = []

    def spy(ctx):
        ran.append("spy")
        ctx.cdlt.note("custom-pass: executed")

    def no_unroll(ctx):
        ran.append("unroll-override")

    acg.extra_passes.append(("after:granularize", "spy", spy))
    acg.pass_overrides["unroll"] = no_unroll
    art = repro.compile(library.gemm(4, 8, 4, in_dtype="u8"), acg,
                        cache=False)
    assert ran == ["spy", "unroll-override"]
    assert any("custom-pass: executed" in n for n in art.schedule_notes)
    assert "spy" in art.pipeline.names
    # the override suppressed unrolling: no unroll note on the codelet
    assert not any(n.startswith("unroll:") for n in art.schedule_notes)


def test_explicit_pipeline_argument():
    marks = []
    pl = Pipeline.default().insert_before(
        "codegen", "mark", lambda ctx: marks.append(ctx.cdlt.name))
    art = repro.compile(library.elementwise("MUL", 32, "i32"), "hvx",
                        pipeline=pl, cache=False)
    assert marks == [art.codelet.name]


def test_schedule_wrapper_runs_acg_hooks():
    """The thin scheduler.schedule wrapper also honours ACG hooks."""
    acg = targets.get_target("dnnweaver")
    acg.extra_passes.append(
        ("before:place", "tag", lambda ctx: ctx.cdlt.note("tag: hello")))
    sched = scheduler.schedule(library.gemm(4, 8, 4, in_dtype="u8"), acg)
    assert sched.schedule_notes[0] == "tag: hello"


# ---------------------------------------------------------------------------
# options unification + misc artifact surface
# ---------------------------------------------------------------------------


def test_schedule_config_is_compile_options():
    assert scheduler.ScheduleConfig is repro.CompileOptions
    assert hash(repro.CompileOptions()) == hash(repro.CompileOptions())


def test_max_mnemonics_option_travels_to_codegen():
    art = repro.compile(library.gemm(64, 64, 64, in_dtype="u8"), "hvx",
                        repro.CompileOptions(max_mnemonics=10), cache=False)
    with pytest.raises(StreamTooLarge):
        art.program  # codegen is lazy; the guard fires on first touch


def test_large_layer_analytics_without_program():
    """Table-2-scale layers are served by analytic cycles alone — compiling
    must not eagerly expand the (too large) mnemonic stream."""
    art = repro.compile("BERT-LG-GEMM1", "hvx")
    assert art.cycles() > 0
    assert "program" not in art.ctx.state


def test_compile_many_batches_and_caches():
    repro.clear_cache()
    items = [library.gemm(4, 8, 4, in_dtype="u8"),
             library.elementwise("ADD", 16, "i32"),
             "DLRM-FC4"]
    arts = repro.compile_many(items, target="dnnweaver")
    assert len(arts) == 3
    again = repro.compile_many(items, target="dnnweaver")
    assert all(a is b for a, b in zip(arts, again))


def test_search_option_routes_through_driver():
    """CompileOptions(search=...) produces a cached artifact with the trace
    attached, keyed separately from the heuristic compile."""
    repro.clear_cache()
    sopts = repro.SearchOptions(generations=3, population=8, seed=0)
    cdlt = library.gemm(24, 32, 16, in_dtype="u8")
    heur = repro.compile(cdlt, "hvx")
    art = repro.compile(cdlt, "hvx", repro.CompileOptions(search=sopts))
    assert art.cycles() <= heur.cycles()
    assert art.search is not None
    assert art.search.trace and art.search.evaluated > 0
    assert art.search.heuristic_cycles == heur.cycles()
    assert art.key != heur.key
    again = repro.compile(cdlt, "hvx", repro.CompileOptions(search=sopts))
    assert again is art  # searched winner served from the cache, no re-search


def test_search_artifact_runs_correctly(rng):
    """The searched schedule's mnemonic stream still matches the oracle."""
    cdlt = library.gemm(8, 16, 12, in_dtype="u8")
    art = repro.compile(
        cdlt, "hvx",
        repro.CompileOptions(search=repro.SearchOptions(
            strategy="exhaustive", max_candidates=64)),
        cache=False)
    assert art.verify(random_inputs(cdlt, rng, 0, 5))


def test_store_option_accepts_path(tmp_path):
    """CompileOptions(store=<path>) resolves to a shared ArtifactStore and
    does not perturb the cache key (a store is a location, not an input)."""
    repro.clear_cache()
    stored = repro.compile(
        library.gemm(8, 16, 12, in_dtype="u8"), "hvx",
        repro.CompileOptions(store=str(tmp_path)))
    plain = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx")
    assert plain is stored  # same key: the in-process tier answered
    repro.clear_cache()
    warm = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx",
                         repro.CompileOptions(store=str(tmp_path)))
    assert warm.ctx.executed == [] and warm.cycles() == stored.cycles()


def test_search_option_must_be_search_options():
    with pytest.raises(TypeError):
        repro.compile(library.gemm(4, 8, 4, in_dtype="u8"), "hvx",
                      repro.CompileOptions(search={"strategy": "grid"}),
                      cache=False)


def test_custom_stage_fingerprint_is_process_stable(tmp_path):
    """Custom pass fns are fingerprinted by source hash, not object id, so
    a BYOC target's store keys survive process restarts.  Emulate two
    processes by importing the same hook module twice."""
    import importlib.util

    mod_file = tmp_path / "hookmod.py"
    mod_file.write_text("def no_unroll(ctx):\n    pass\n")

    def load(name):
        spec = importlib.util.spec_from_file_location(name, mod_file)
        mod = importlib.util.module_from_spec(spec)
        spec.loader.exec_module(mod)
        return mod.no_unroll

    fn_a, fn_b = load("hookmod_a"), load("hookmod_b")
    assert fn_a is not fn_b
    fp_a = Pipeline.default().override("unroll", fn_a).fingerprint()
    fp_b = Pipeline.default().override("unroll", fn_b).fingerprint()
    assert fp_a == fp_b
    import re  # the custom stage carries a source-hash tag, not an id
    assert re.search(r"unroll:.*:[0-9a-f]{16}(;|$)", fp_a)


def test_closure_captures_distinguish_stage_fingerprints():
    """Two closures from one factory with different captured parameters
    must NOT alias to the same cache key."""
    def make_stage(factor):
        def stage(ctx):
            ctx.cdlt.note(f"custom: {factor}")
        return stage

    fp2 = Pipeline.default().override("unroll", make_stage(2)).fingerprint()
    fp8 = Pipeline.default().override("unroll", make_stage(8)).fingerprint()
    assert fp2 != fp8
    # and the same capture is stable across factory calls
    assert fp2 == Pipeline.default().override(
        "unroll", make_stage(2)).fingerprint()


def test_register_target():
    repro.register_target("hvx_nounroll", targets.hvx_acg,
                          pass_overrides={"unroll": lambda ctx: None})
    try:
        assert "hvx_nounroll" in repro.available_targets()
        art = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"),
                            "hvx_nounroll", cache=False)
        assert not any(n.startswith("unroll:") for n in art.schedule_notes)
        # same mnemonics as an explicit unroll=False compile on stock hvx
        ref = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx",
                            repro.CompileOptions(unroll=False), cache=False)
        assert [m.encode() for m in art.program.mnemonics] == \
            [m.encode() for m in ref.program.mnemonics]
    finally:
        targets.TARGETS.pop("hvx_nounroll", None)
