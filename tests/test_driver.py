"""The unified compile driver (repro.compile): equivalence with the legacy
manual call chain, content-addressed caching, the pluggable pass pipeline,
and the per-ACG pass-override hook."""
import numpy as np
import pytest

import repro
from repro.core import codegen, cost, library, scheduler, stream, targets
from repro.core.codegen import StreamTooLarge
from repro.core.pipeline import Pipeline

from conftest import random_inputs

CASES = [
    ("hvx", lambda: library.gemm(8, 16, 12, in_dtype="u8")),
    ("hvx", lambda: library.elementwise("ADD", 64, "i32")),
    ("dnnweaver", lambda: library.gemm(8, 16, 12, in_dtype="u8")),
    ("dnnweaver", lambda: library.elementwise("ADD", 64, "i32")),
]


# ---------------------------------------------------------------------------
# (a) equivalence with the legacy manual pipeline
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("target,build", CASES)
def test_compile_matches_legacy_chain(target, build, rng):
    """repro.compile() produces byte-identical mnemonic programs, equal
    analytic cycles, and equal stream outputs to the hand-stitched
    schedule -> generate -> run_stream -> cost chain."""
    cdlt = build()
    acg = targets.get_target(target)
    sched = scheduler.schedule(cdlt, acg)
    prog = codegen.generate(sched, acg)
    ins = random_inputs(cdlt, rng, 0, 5)
    legacy = stream.run_stream(prog, ins)
    legacy_cycles = cost.cost(sched, acg).cycles

    art = repro.compile(build(), target)
    assert [m.encode() for m in art.program.mnemonics] == \
        [m.encode() for m in prog.mnemonics]
    assert [str(m) for m in art.program.mnemonics] == \
        [str(m) for m in prog.mnemonics]
    assert art.cycles() == legacy_cycles
    res = art.run(ins)
    for k in legacy.outputs:
        np.testing.assert_array_equal(res.outputs[k], legacy.outputs[k])
    assert res.serial_cycles == legacy.serial_cycles
    assert art.verify(ins)


def test_layer_key_and_spec_resolution():
    """Paper-layer keys and LayerSpecs resolve to the same artifact as the
    built codelet (content addressing, not object identity)."""
    spec = library.PAPER_LAYERS[6]  # DLRM-FC1: small
    by_key = repro.compile(spec.key, "hvx")
    by_spec = repro.compile(spec, "hvx")
    by_cdlt = repro.compile(spec.build(), "hvx")
    assert by_key is by_spec is by_cdlt


# ---------------------------------------------------------------------------
# (b) content-addressed cache
# ---------------------------------------------------------------------------


def test_cache_hit_returns_same_artifact_without_rerunning():
    repro.clear_cache()
    a1 = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx")
    stages_run = list(a1.ctx.executed)
    a2 = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx")
    assert a2 is a1                       # same artifact object
    assert a1.ctx.executed == stages_run  # no pass re-ran
    stats = repro.cache_stats()
    assert stats["hits"] == 1 and stats["misses"] == 1


def test_cache_misses_on_any_key_component():
    repro.clear_cache()
    base = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx")
    other_target = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"),
                                 "dnnweaver")
    other_opts = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx",
                               repro.CompileOptions(unroll=False))
    other_cdlt = repro.compile(library.gemm(8, 16, 13, in_dtype="u8"), "hvx")
    arts = {id(a) for a in (base, other_target, other_opts, other_cdlt)}
    assert len(arts) == 4
    assert repro.cache_stats()["misses"] == 4


def test_cache_bypass():
    repro.clear_cache()
    a1 = repro.compile(library.gemm(4, 8, 4, in_dtype="u8"), "hvx",
                       cache=False)
    a2 = repro.compile(library.gemm(4, 8, 4, in_dtype="u8"), "hvx",
                       cache=False)
    assert a1 is not a2
    assert repro.cache_stats()["size"] == 0


# ---------------------------------------------------------------------------
# (c) pluggable pipeline + per-ACG override hook
# ---------------------------------------------------------------------------


def test_acg_pass_hooks_execute():
    """A stage override and an extra pass installed on the ACG (BYOC-style)
    both actually run, in pipeline position."""
    acg = targets.get_target("hvx")
    ran = []

    def spy(ctx):
        ran.append("spy")
        ctx.cdlt.note("custom-pass: executed")

    def no_unroll(ctx):
        ran.append("unroll-override")

    acg.extra_passes.append(("after:granularize", "spy", spy))
    acg.pass_overrides["unroll"] = no_unroll
    art = repro.compile(library.gemm(4, 8, 4, in_dtype="u8"), acg,
                        cache=False)
    assert ran == ["spy", "unroll-override"]
    assert any("custom-pass: executed" in n for n in art.schedule_notes)
    assert "spy" in art.pipeline.names
    # the override suppressed unrolling: no unroll note on the codelet
    assert not any(n.startswith("unroll:") for n in art.schedule_notes)


def test_explicit_pipeline_argument():
    marks = []
    pl = Pipeline.default().insert_before(
        "codegen", "mark", lambda ctx: marks.append(ctx.cdlt.name))
    art = repro.compile(library.elementwise("MUL", 32, "i32"), "hvx",
                        pipeline=pl, cache=False)
    assert marks == [art.codelet.name]


def test_schedule_wrapper_runs_acg_hooks():
    """The thin scheduler.schedule wrapper also honours ACG hooks."""
    acg = targets.get_target("dnnweaver")
    acg.extra_passes.append(
        ("before:place", "tag", lambda ctx: ctx.cdlt.note("tag: hello")))
    sched = scheduler.schedule(library.gemm(4, 8, 4, in_dtype="u8"), acg)
    assert sched.schedule_notes[0] == "tag: hello"


# ---------------------------------------------------------------------------
# options unification + misc artifact surface
# ---------------------------------------------------------------------------


def test_schedule_config_is_compile_options():
    assert scheduler.ScheduleConfig is repro.CompileOptions
    assert hash(repro.CompileOptions()) == hash(repro.CompileOptions())


def test_max_mnemonics_option_travels_to_codegen():
    art = repro.compile(library.gemm(64, 64, 64, in_dtype="u8"), "hvx",
                        repro.CompileOptions(max_mnemonics=10), cache=False)
    with pytest.raises(StreamTooLarge):
        art.program  # codegen is lazy; the guard fires on first touch


def test_large_layer_analytics_without_program():
    """Table-2-scale layers are served by analytic cycles alone — compiling
    must not eagerly expand the (too large) mnemonic stream."""
    art = repro.compile("BERT-LG-GEMM1", "hvx")
    assert art.cycles() > 0
    assert "program" not in art.ctx.state


def test_compile_many_batches_and_caches():
    repro.clear_cache()
    items = [library.gemm(4, 8, 4, in_dtype="u8"),
             library.elementwise("ADD", 16, "i32"),
             "DLRM-FC4"]
    arts = repro.compile_many(items, target="dnnweaver")
    assert len(arts) == 3
    again = repro.compile_many(items, target="dnnweaver")
    assert all(a is b for a, b in zip(arts, again))


def test_register_target():
    repro.register_target("hvx_nounroll", targets.hvx_acg,
                          pass_overrides={"unroll": lambda ctx: None})
    try:
        assert "hvx_nounroll" in repro.available_targets()
        art = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"),
                            "hvx_nounroll", cache=False)
        assert not any(n.startswith("unroll:") for n in art.schedule_notes)
        # same mnemonics as an explicit unroll=False compile on stock hvx
        ref = repro.compile(library.gemm(8, 16, 12, in_dtype="u8"), "hvx",
                            repro.CompileOptions(unroll=False), cache=False)
        assert [m.encode() for m in art.program.mnemonics] == \
            [m.encode() for m in ref.program.mnemonics]
    finally:
        targets.TARGETS.pop("hvx_nounroll", None)
