"""Disk-backed ArtifactStore (core/store.py): warm restores run zero
pipeline stages and rebuild lazily; corrupt entries fall back to a clean
recompile; the size bound evicts LRU; ``clear_cache(disk=True)`` empties
it; and a *fresh process* replays a warm sweep as store hits only."""
import json
import os
import subprocess
import sys

import pytest

import repro
from repro.core import library
from repro.core.store import ArtifactStore

pytestmark = pytest.mark.store

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture
def store(tmp_path):
    repro.clear_cache()
    yield ArtifactStore(str(tmp_path / "store"))
    repro.clear_cache()


def _gemm(k=16):
    return library.gemm(24, 32, k, in_dtype="u8")


# ---------------------------------------------------------------------------
# warm restore semantics
# ---------------------------------------------------------------------------


def test_warm_restore_runs_zero_stages_and_replays_identically(store):
    opts = repro.CompileOptions(store=store)
    a1 = repro.compile(_gemm(), "hvx", opts)
    cycles = a1.cycles()
    program = [m.encode() for m in a1.program.mnemonics]
    notes = list(a1.schedule_notes)

    repro.clear_cache()  # simulate a fresh process (disk survives)
    a2 = repro.compile(_gemm(), "hvx", opts)
    assert a2.ctx.executed == []            # no pass ran on the warm hit
    assert a2.cycles() == cycles            # analytics from the stored report
    assert a2.ctx.executed == []            # ...still without any pass
    assert a2.schedule_notes == notes
    assert repro.cache_stats()["store_hits"] == 1
    # lazy rebuild: touching .program replays the stored schedule decisions
    assert [m.encode() for m in a2.program.mnemonics] == program
    assert "tile" in a2.ctx.executed


def test_searched_artifact_roundtrips_with_trace(store):
    opts = repro.CompileOptions(
        store=store, search=repro.SearchOptions(generations=3, population=8,
                                                seed=0))
    a1 = repro.compile(_gemm(), "hvx", opts)
    assert a1.search is not None and a1.search.trace
    repro.clear_cache()
    a2 = repro.compile(_gemm(), "hvx", opts)
    assert a2.ctx.executed == []
    assert a2.cycles() == a1.cycles()
    assert a2.search is not None
    assert [tuple(t) for t in a2.search.trace] == \
        [tuple(t) for t in a1.search.trace]
    assert a2.search.point == a1.search.point
    # replay (no re-search) reproduces the searched program exactly
    assert [m.encode() for m in a2.program.mnemonics] == \
        [m.encode() for m in a1.program.mnemonics]


# ---------------------------------------------------------------------------
# robustness
# ---------------------------------------------------------------------------


def test_corrupt_entry_falls_back_to_clean_recompile(store):
    opts = repro.CompileOptions(store=store)
    a1 = repro.compile(_gemm(), "hvx", opts)
    path = os.path.join(store.root, a1.key + ".json")
    with open(path, "w") as f:
        f.write('{"format": 1, "key": "tru')  # truncated write
    repro.clear_cache()
    a2 = repro.compile(_gemm(), "hvx", opts)
    assert a2.cycles() == a1.cycles()
    assert a2.ctx.executed                 # really recompiled
    assert store.stats["corrupt"] == 1
    assert os.path.exists(path)            # fresh entry rewritten after


def test_stale_compiler_signature_forces_recompile(store):
    """An entry written by a different compiler version reads as a miss
    (and is deleted): persisted keys cover inputs, not the compiler."""
    opts = repro.CompileOptions(store=store)
    a1 = repro.compile(_gemm(), "hvx", opts)
    path = os.path.join(store.root, a1.key + ".json")
    entry = json.load(open(path))
    entry["compiler"] = "0badc0de0badc0de"
    json.dump(entry, open(path, "w"))
    repro.clear_cache()
    a2 = repro.compile(_gemm(), "hvx", opts)
    assert a2.ctx.executed and a2.cycles() == a1.cycles()
    assert store.stats["stale"] == 1
    assert json.load(open(path))["compiler"] != "0badc0de0badc0de"


def test_semantically_broken_entry_falls_back(store):
    opts = repro.CompileOptions(store=store)
    a1 = repro.compile(_gemm(), "hvx", opts)
    path = os.path.join(store.root, a1.key + ".json")
    entry = json.load(open(path))
    entry["reports"] = {"1": {"bogus_field": 1}}  # schema drift
    json.dump(entry, open(path, "w"))
    repro.clear_cache()
    a2 = repro.compile(_gemm(), "hvx", opts)
    assert a2.ctx.executed and a2.cycles() == a1.cycles()
    assert store.stats["corrupt"] == 1


# ---------------------------------------------------------------------------
# size bound / LRU
# ---------------------------------------------------------------------------


def test_size_bound_evicts_least_recently_used(tmp_path):
    repro.clear_cache()
    st = ArtifactStore(str(tmp_path), max_bytes=1)  # everything over budget
    opts = repro.CompileOptions(store=st)
    arts = [repro.compile(_gemm(k), "hvx", opts) for k in (8, 16, 24)]
    # bound of 1 byte: every put evicts all older entries; newest survives
    assert st.keys() == [arts[-1].key]
    assert st.stats["evictions"] == 2
    repro.clear_cache()


def test_load_bumps_lru_recency(tmp_path):
    repro.clear_cache()
    st = ArtifactStore(str(tmp_path), max_bytes=10 ** 9)
    opts = repro.CompileOptions(store=st)
    a_old = repro.compile(_gemm(8), "hvx", opts)
    a_new = repro.compile(_gemm(16), "hvx", opts)
    # age both entries, then touch the *older* one via a warm load
    for art, age in ((a_old, 2000), (a_new, 1000)):
        p = os.path.join(st.root, art.key + ".json")
        past = os.stat(p).st_mtime - age
        os.utime(p, (past, past))
    assert st.load(a_old.key) is not None   # bumps a_old to most recent
    # shrink the bound so exactly one entry must go: the LRU is now a_new
    st.max_bytes = st.size_bytes() - 1
    st._evict()
    keys = set(st.keys())
    assert a_old.key in keys
    assert a_new.key not in keys
    assert st.stats["evictions"] == 1
    repro.clear_cache()


# ---------------------------------------------------------------------------
# ACG identity: spec-fingerprint keys (no aliasing by name)
# ---------------------------------------------------------------------------


def test_same_name_variants_never_alias_in_the_store(store):
    """Regression: two derived variants sharing a base *name* must key by
    spec content, so neither can serve the other's warm entry."""
    from repro.core import targets
    from repro.core.acg import ACG

    opts = repro.CompileOptions(store=store)
    base = ACG.from_spec(targets.DNNWEAVER_SPEC)
    # same registered name 'dnnweaver', different covenant
    variant = ACG.from_spec(targets.DNNWEAVER_SPEC.derive(
        pe="32x32", name="dnnweaver"))
    assert base.name == variant.name == "dnnweaver"

    a = repro.compile("DLRM-FC1", base, opts)
    b = repro.compile("DLRM-FC1", variant, opts)
    assert a.key != b.key
    assert a.cycles() != b.cycles()
    assert len(store) == 2

    repro.clear_cache()  # fresh process; disk survives
    warm_b = repro.compile("DLRM-FC1", variant, opts)
    warm_a = repro.compile("DLRM-FC1", base, opts)
    assert warm_a.ctx.executed == [] and warm_b.ctx.executed == []
    assert warm_a.cycles() == a.cycles()
    assert warm_b.cycles() == b.cycles()
    assert repro.cache_stats()["store_hits"] == 2


def test_mutated_acg_cannot_ride_a_stale_key(store):
    """Mutating a resolved ACG — including mnemonic *field layouts*, which
    the old describe()-based hash ignored — re-fingerprints it, so the next
    compile misses instead of collecting a stale warm hit."""
    from repro.core import targets
    from repro.core.acg import MnemonicDef, ifield

    opts = repro.CompileOptions(store=store)
    acg = targets.get_target("hvx")
    a1 = repro.compile(_gemm(), acg, opts)
    old = acg.mnemonics["LOOPI"]
    acg.mnemonics["LOOPI"] = MnemonicDef(
        "LOOPI", old.opcode, (ifield("LEVEL", 16), ifield("TRIP", 32)))
    a2 = repro.compile(_gemm(), acg, opts)
    assert a2.key != a1.key
    assert a2 is not a1


def test_mutated_name_resolved_acg_is_rebuilt_pristine(store):
    """The string-name resolution path, like the spec path, rebuilds a
    pristine graph when the shared memoized instance has been mutated —
    'hvx' always compiles the architecture registered under that name."""
    from repro.core import targets
    from repro.core.acg import MnemonicDef, ifield

    opts = repro.CompileOptions(store=store)
    a1 = repro.compile(_gemm(), "hvx", opts)
    shared = a1.acg
    old = shared.mnemonics["LOOPI"]
    shared.mnemonics["LOOPI"] = MnemonicDef(
        "LOOPI", old.opcode, (ifield("LEVEL", 16), ifield("TRIP", 32)))
    a2 = repro.compile(_gemm(8), "hvx", opts)
    assert a2.acg is not shared
    assert a2.acg.to_spec().fingerprint() == targets.HVX_SPEC.fingerprint()


def test_mutated_spec_resolved_acg_is_rebuilt_pristine(store):
    """The ACGSpec resolution path memoizes the built graph, but a spec is
    a *pristine* description: if the shared instance drifts (mutation),
    the next resolve rebuilds from the spec instead of compiling the
    mutated graph under the spec's key."""
    from repro.core import targets
    from repro.core.acg import MnemonicDef, ifield

    opts = repro.CompileOptions(store=store)
    a1 = repro.compile(_gemm(), targets.HVX_SPEC, opts)
    shared = a1.acg  # the memoized instance behind the spec target
    old = shared.mnemonics["LOOPI"]
    shared.mnemonics["LOOPI"] = MnemonicDef(
        "LOOPI", old.opcode, (ifield("LEVEL", 16), ifield("TRIP", 32)))
    assert shared.to_spec().fingerprint() != targets.HVX_SPEC.fingerprint()
    # resolution detects the drift and rebuilds a faithful graph
    from repro.core.driver import _resolve_target
    acg2, fp2 = _resolve_target(targets.HVX_SPEC)
    assert acg2 is not shared
    assert fp2 == targets.HVX_SPEC.fingerprint()
    assert acg2.to_spec().fingerprint() == fp2
    # the key identity is therefore the pristine spec's, before and after:
    # a fresh process (in-process cache cleared) warm-restores a1's entry
    repro.clear_cache()
    a2 = repro.compile(_gemm(), targets.HVX_SPEC, opts)
    assert a2.key == a1.key and a2.ctx.executed == []
    assert a2.acg is not shared


# ---------------------------------------------------------------------------
# clearing
# ---------------------------------------------------------------------------


def test_clear_cache_disk_empties_store(store):
    opts = repro.CompileOptions(store=store)
    repro.compile(_gemm(), "hvx", opts)
    repro.compile(_gemm(8), "hvx", opts)
    assert len(store) == 2
    repro.clear_cache(disk=True, store=store)
    assert len(store) == 0
    assert repro.cache_stats()["size"] == 0


def test_in_process_hit_backfills_late_configured_store(tmp_path):
    """A key compiled before the store existed is persisted the next time
    it is requested with a store configured — warm replay still works."""
    repro.clear_cache()
    plain = repro.compile(_gemm(), "hvx")             # no store yet
    st = ArtifactStore(str(tmp_path))
    hit = repro.compile(_gemm(), "hvx", repro.CompileOptions(store=st))
    assert hit is plain and plain.key in st           # backfilled on the hit
    repro.clear_cache()
    warm = repro.compile(_gemm(), "hvx", repro.CompileOptions(store=st))
    assert warm.ctx.executed == [] and warm.cycles() == plain.cycles()
    repro.clear_cache()


def test_unusable_env_store_disables_disk_tier(tmp_path, monkeypatch):
    """A bad REPRO_CACHE_DIR must not fail compiles — it warns once and
    runs memory-only."""
    target = tmp_path / "blocker"
    target.write_text("not a directory")
    monkeypatch.setenv("REPRO_CACHE_DIR", str(target / "store"))
    repro.clear_cache()
    with pytest.warns(UserWarning, match="REPRO_CACHE_DIR"):
        art = repro.compile(_gemm(), "hvx")
    assert art.cycles() > 0
    repro.compile(_gemm(8), "hvx")  # no second warning, still compiles
    repro.clear_cache()


def test_env_var_names_default_store(tmp_path, monkeypatch):
    repro.clear_cache()
    monkeypatch.setenv("REPRO_CACHE_DIR", str(tmp_path / "envstore"))
    art = repro.compile(library.gemm(12, 8, 4, in_dtype="u8"), "hvx")
    assert os.path.exists(
        os.path.join(str(tmp_path / "envstore"), art.key + ".json"))
    repro.clear_cache()


# ---------------------------------------------------------------------------
# the multi-process contract
# ---------------------------------------------------------------------------

_SWEEP = r"""
import json, sys
import repro
from repro.core import library

items = [library.gemm(24, 32, 16, in_dtype="u8"),
         library.gemm(8, 16, 12, in_dtype="u8"),
         "DLRM-FC4"]
arts = repro.compile_many(items, target="hvx")
arts += [repro.compile(
    library.gemm(24, 32, 16, in_dtype="u8"), "dnnweaver",
    repro.CompileOptions(search=repro.SearchOptions(generations=2,
                                                    population=6)))]
print(json.dumps({
    "cycles": [a.cycles() for a in arts],
    "stages_run": sum(len(a.ctx.executed) for a in arts),
    "stats": repro.cache_stats(),
}))
"""


# Two processes hammer one store whose size bound forces an eviction scan
# on every put.  The regression this guards: concurrent LRU evictions used
# to delete *each other's* just-written entries (both processes scan, both
# see the other's fresh file as LRU-eligible).  The hardened store
# serialises eviction behind a FileLock and never evicts a foreign entry
# younger than FRESH_GRACE, so every process must still see its own entry
# immediately after each put.
_EVICT_STRESS = r"""
import hashlib, os, sys
from repro.core.store import ArtifactStore

root, tag = sys.argv[1], sys.argv[2]
st = ArtifactStore(root, max_bytes=2000)  # a handful of entries
pad = "x" * 400
for i in range(30):
    key = hashlib.sha256(f"{tag}-{i}".encode()).hexdigest()
    st.put(key, {"reports": {}, "pack": True, "pad": pad})
    if not os.path.exists(os.path.join(root, key + ".json")):
        print(f"LOST fresh entry {tag}-{i}", file=sys.stderr)
        sys.exit(1)
print(f"{tag} ok evictions={st.stats['evictions']}")
"""


def test_concurrent_evicting_writers_never_lose_fresh_entries(tmp_path):
    env = dict(os.environ, PYTHONPATH="src")
    procs = [subprocess.Popen(
        [sys.executable, "-c", _EVICT_STRESS, str(tmp_path / "shared"), tag],
        stdout=subprocess.PIPE, stderr=subprocess.PIPE, text=True,
        env=env, cwd=ROOT) for tag in ("alpha", "beta")]
    for p in procs:
        out, err = p.communicate(timeout=600)
        assert p.returncode == 0, err
        assert "ok" in out


def test_own_entries_still_evict_under_size_pressure(tmp_path):
    """The foreign-fresh grace window must not break the single-process
    size bound: a process's own fresh entries remain evictable."""
    st = ArtifactStore(str(tmp_path), max_bytes=1)
    st.put("a" * 64, {"reports": {}, "pack": True})
    st.put("b" * 64, {"reports": {}, "pack": True})
    assert st.keys() == ["b" * 64]
    assert st.stats["evictions"] == 1


# ---------------------------------------------------------------------------
# locks, claims, journal, gc
# ---------------------------------------------------------------------------


def test_filelock_excludes_and_breaks_stale(tmp_path):
    from repro.core.store import FileLock
    path = str(tmp_path / "x.lock")
    a = FileLock(path)
    assert a.acquire()
    assert not FileLock(path).acquire(timeout=0.05)  # held: excluded
    a.release()
    b = FileLock(path, stale_timeout=60)
    assert b.acquire(timeout=0.05)                   # released: free again
    # simulate a dead holder: backdate the lock past the stale timeout
    past = os.stat(path).st_mtime - 3600
    os.utime(path, (past, past))
    c = FileLock(path, stale_timeout=60)
    assert c.acquire(timeout=1.0)                    # stale lock broken
    c.release()


def test_claims_are_exclusive_released_and_reclaimed(tmp_path):
    st = ArtifactStore(str(tmp_path))
    key = "c" * 64
    assert st.claim("s1", key, "w1")
    assert not st.claim("s1", key, "w2")             # held by w1
    st.release_claim("s1", key, "w2")                # not w2's to release
    assert not st.claim("s1", key, "w2")
    st.release_claim("s1", key, "w1")
    assert st.claim("s1", key, "w2")                 # properly released
    path = st._claim_path("s1", key)
    past = os.stat(path).st_mtime - 3600
    os.utime(path, (past, past))
    assert st.claim("s1", key, "w3", stale_timeout=60)  # stale: reclaimed
    assert st.stats["reclaims"] == 1


def test_journal_is_monotonic_and_readable(tmp_path):
    st = ArtifactStore(str(tmp_path))
    j = st.journal("sweepid")
    seqs = [j.append({"event": "compiled", "key": f"{i:064x}"})
            for i in range(5)]
    assert seqs == [1, 2, 3, 4, 5]
    recs = j.read()
    assert [r["seq"] for r in recs] == seqs
    assert st.journal("sweepid").append({"event": "dedup"}) == 6
    assert j.compile_counts() == {f"{i:064x}": 1 for i in range(5)}


def test_gc_by_age_size_and_stale_claims(tmp_path):
    st = ArtifactStore(str(tmp_path), max_bytes=10 ** 9)
    young, old = "d" * 64, "e" * 64
    for key in (young, old):
        st.put(key, {"reports": {}, "pack": True})
    past = os.stat(st._path(old)).st_mtime - 7200
    os.utime(st._path(old), (past, past))
    st.claim("s2", "f" * 64, "dead-worker")
    cpath = st._claim_path("s2", "f" * 64)
    os.utime(cpath, (past, past))
    out = st.gc(max_age=3600)
    assert out["aged"] == 1 and out["claims_reaped"] == 1
    assert st.keys() == [young]
    assert not os.path.exists(cpath)
    # size-driven gc: shrink the budget so the survivor must go too
    out = st.gc(max_bytes=0)
    assert out["evicted"] >= 0  # keep-newest still protects one entry
    st.put("a1" * 32, {"reports": {}, "pack": True})
    st.put("b2" * 32, {"reports": {}, "pack": True})
    st.gc(max_bytes=1)
    assert len(st) >= 1  # bounded, but never empties the newest entry


def test_peek_reads_without_stats_or_recency(store):
    opts = repro.CompileOptions(store=store)
    art = repro.compile(_gemm(), "hvx", opts)
    hits_before = dict(store.stats)
    entry = store.peek(art.key)
    assert entry is not None and entry["key"] == art.key
    assert store.stats == hits_before          # no stats movement
    assert store.peek("0" * 64) is None        # miss is just None
    from repro.core.store import entry_cycles
    assert entry_cycles(entry) == art.cycles()


def test_second_process_warm_sweep_is_store_hits_only(tmp_path):
    """A fresh process compiling a warm sweep executes ZERO scheduling or
    search passes — every artifact restores from the disk store."""
    env = dict(os.environ, PYTHONPATH="src",
               REPRO_CACHE_DIR=str(tmp_path / "store"))

    def run():
        r = subprocess.run([sys.executable, "-c", _SWEEP],
                           capture_output=True, text=True, env=env, cwd=ROOT,
                           timeout=600)
        assert r.returncode == 0, r.stderr[-2000:]
        return json.loads(r.stdout.strip().splitlines()[-1])

    cold = run()
    warm = run()
    assert cold["stats"]["store_misses"] == 4
    assert cold["stages_run"] > 0
    assert warm["stats"]["store_hits"] == 4
    assert warm["stats"]["store_misses"] == 0
    assert warm["stages_run"] == 0          # no scheduling/search pass ran
    assert warm["cycles"] == cold["cycles"]


# ---------------------------------------------------------------------------
# PR 5: warm-start index + race pins
# ---------------------------------------------------------------------------


def test_warm_start_index_built_from_journal_and_entries(store):
    """The index joins sweep-journal events with stored entries: every
    journaled compile with a tiling becomes a candidate point, and
    ``seeds`` returns only points valid for the requesting space."""
    from repro.core.scheduler import schedule_space
    from repro.core.store import WarmStartIndex

    report = repro.sweep(["DLRM-FC2", "DLRM-FC3"], ["hvx"], store=store)
    assert report.counts()["ok"] == 2
    idx = WarmStartIndex.from_store(store)
    assert len(idx) == 2

    acg = repro.targets.get("hvx")
    space = schedule_space(library.paper_layer("DLRM-FC2"), acg)
    seeds = idx.seeds(space, (1, 2, 4, 8), limit=4)
    assert seeds
    for tiling, unroll in seeds:
        assert set(tiling) == set(space.divisors)
        assert space.valid(tiling)
        assert unroll in (1, 2, 4, 8)


def test_warm_start_index_prefers_exact_space_signature(store):
    """Searched entries record their space signature; seeds from the SAME
    shape rank before merely-compatible foreign points."""
    from repro.core.scheduler import schedule_space
    from repro.core.store import WarmStartIndex
    from repro.core.search import SearchOptions

    sopts = SearchOptions(strategy="beam", generations=2, population=6,
                          seed=0, max_candidates=128)
    art = repro.compile("DLRM-FC4", "hvx",
                        repro.CompileOptions(search=sopts, store=store))
    sig = art.search.space_sig
    idx = WarmStartIndex.from_store(store)
    acg = repro.targets.get("hvx")
    space = schedule_space(library.paper_layer("DLRM-FC4"), acg)
    assert space.signature() == sig
    seeds = idx.seeds(space, (1, 2, 4, 8), limit=1)
    assert seeds and seeds[0][0] == art.search.point["tiling"]


def test_pins_roundtrip_atomically_and_clear(store):
    rec = {"layer": "L", "target": "hvx", "key": "a" * 64,
           "strategy": "beam", "cycles": 123.0,
           "point": {"tiling": {"m": 4}, "unroll_factor": 2}}
    name = store.pin_name("L", "hvx@pe=8x8")
    assert "/" not in name
    store.pin(name, rec)
    got = store.load_pin(name)
    assert got is not None and got["cycles"] == 123.0
    assert got["pin"] == name
    assert name in store.pins()
    assert store.load_pin("nope") is None
    store.clear()
    assert store.pins() == {}


def test_warm_start_index_consumes_pins(store):
    from repro.core.scheduler import schedule_space
    from repro.core.store import WarmStartIndex

    acg = repro.targets.get("hvx")
    space = schedule_space(library.paper_layer("DLRM-FC4"), acg)
    tiling = space.tilings[0]
    store.pin(store.pin_name("DLRM-FC4", "hvx"),
              {"layer": "DLRM-FC4", "target": "hvx", "key": "0" * 64,
               "strategy": "beam", "cycles": 1.0,
               "space_sig": space.signature(),
               "point": {"tiling": tiling, "unroll_factor": 4}})
    idx = WarmStartIndex.from_store(store)
    seeds = idx.seeds(space, (1, 2, 4, 8), limit=2)
    assert (tiling, 4) in [(t, u) for t, u in seeds]


def test_warm_start_index_rejects_foreign_shapes(store):
    """Points whose loop-var set does not match the requesting space are
    never returned — a conv schedule cannot seed a GEMM."""
    from repro.core.scheduler import schedule_space
    from repro.core.store import WarmStartIndex

    repro.compile(library.elementwise("ADD", 64, "i32"), "hvx",
                  repro.CompileOptions(store=store))
    idx = WarmStartIndex.from_store(store)
    assert len(idx) >= 1
    acg = repro.targets.get("hvx")
    space = schedule_space(_gemm(), acg)
    assert idx.seeds(space, (1, 2, 4, 8), limit=4) == []
