"""Multi-process sweep coordinator (core/sweep.py): deterministic plan
expansion and partitioning, dedup against the shared artifact store,
claim-based external workers with stale-claim reclaim, report merge
identity vs sequential ``compile_many``, and the exactly-once journal
contract across worker processes."""
import json
import os
import subprocess
import sys

import pytest

import repro
from repro.core import sweep as sweep_mod
from repro.core.store import ArtifactStore
from repro.core.sweep import (SweepReport, UnitResult, expand_plan,
                              partition, plan_id, run_external_worker)

pytestmark = pytest.mark.sweep

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
LAYERS = ["DLRM-FC2", "DLRM-FC3", "DLRM-FC4"]
VARIANTS = ["dnnweaver@pe=32x32", "dnnweaver@pe=16x16"]


@pytest.fixture
def store(tmp_path):
    repro.clear_cache()
    yield ArtifactStore(str(tmp_path / "store"))
    repro.clear_cache()


# ---------------------------------------------------------------------------
# plan expansion + partition determinism
# ---------------------------------------------------------------------------


def test_expand_plan_is_deterministic_and_order_independent():
    a = expand_plan(LAYERS, VARIANTS)
    b = expand_plan(list(reversed(LAYERS)), list(reversed(VARIANTS)))
    assert [u.key for u in a] == [u.key for u in b]
    assert len(a) == len(LAYERS) * len(VARIANTS)
    assert [u.key for u in a] == sorted(u.key for u in a)
    # duplicates collapse onto the same content-addressed unit
    c = expand_plan(LAYERS + LAYERS, VARIANTS)
    assert [u.key for u in c] == [u.key for u in a]
    assert plan_id(a) == plan_id(b) == plan_id(c)


def test_partition_is_deterministic_and_complete():
    units = expand_plan(LAYERS, VARIANTS)
    shards = partition(units, 2)
    again = partition(list(reversed(units)), 2)  # input order irrelevant
    assert [[u.key for u in s] for s in shards] == \
        [[u.key for u in s] for s in again]
    flat = [u.key for s in shards for u in s]
    assert sorted(flat) == [u.key for u in units]  # complete + disjoint
    assert abs(len(shards[0]) - len(shards[1])) <= 1  # balanced
    # more workers than units: spare shards are just empty
    wide = partition(units, len(units) + 3)
    assert sum(len(s) for s in wide) == len(units)


def test_search_axis_creates_distinct_units():
    searches = [None, repro.SearchOptions(generations=2, population=4,
                                          seed=0)]
    units = expand_plan(["DLRM-FC4"], ["hvx"], searches=searches)
    assert len(units) == 2
    assert {u.opt for u in units} == \
        {"heuristic", "search:evolutionary@g2p4s0"}


def test_workunit_json_roundtrip():
    searches = [repro.SearchOptions(generations=2, population=4, seed=3)]
    for unit in expand_plan(LAYERS[:1], VARIANTS, searches=searches):
        back = sweep_mod.WorkUnit.from_json(
            json.loads(json.dumps(unit.to_json())))
        assert back == unit


# ---------------------------------------------------------------------------
# serial backend: merge identity vs sequential compile_many
# ---------------------------------------------------------------------------


def test_serial_sweep_matches_sequential_compile_many(store):
    pairs = [(layer, v) for layer in LAYERS for v in VARIANTS]
    arts = repro.compile_many(pairs)
    expected = {a.key: a.cycles() for a in arts}
    report = repro.sweep(LAYERS, VARIANTS, store=store)
    assert report.cycles_by_key() == expected
    assert report.counts()["ok"] == len(pairs)
    assert len(store) == len(pairs)  # every unit persisted


def test_report_merge_is_identity_and_idempotent():
    full = SweepReport(sweep_id="s", results=[
        UnitResult(key=f"{i:02x}", layer=f"L{i % 3}", target="t",
                   cycles=float(i), source="compiled")
        for i in range(6)])
    parts = [SweepReport(sweep_id="s", results=full.results[:2]),
             SweepReport(sweep_id="s", results=full.results[2:]),
             SweepReport(sweep_id="s", results=full.results[1:4])]
    merged = SweepReport.merge(parts)
    assert merged.cycles_by_key() == full.cycles_by_key()
    again = SweepReport.merge([merged, merged])
    assert again.cycles_by_key() == full.cycles_by_key()
    # an ok record beats a skipped one for the same key, whatever the order
    skip = UnitResult(key="00", layer="L0", target="t", status="skipped")
    m = SweepReport.merge([SweepReport(sweep_id="s", results=[skip]), full])
    assert m.cycles_by_key()["00"] == 0.0


def test_best_by_layer_picks_lowest_cycles():
    rep = SweepReport(sweep_id="s", results=[
        UnitResult(key="aa", layer="L", target="big", cycles=100.0),
        UnitResult(key="ab", layer="L", target="small", cycles=40.0),
        UnitResult(key="ac", layer="L", target="broken", status="failed"),
    ])
    best = rep.best_by_layer()
    assert best["L"].target == "small"
    assert "small" in rep.best_table()


# ---------------------------------------------------------------------------
# dedup against the store
# ---------------------------------------------------------------------------


def test_dedup_skips_already_stored_units(store):
    warm_key = repro.compile(LAYERS[0], VARIANTS[0],
                             repro.CompileOptions(store=store)).key
    repro.clear_cache()
    report = repro.sweep(LAYERS, VARIANTS, store=store)
    by_key = {r.key: r for r in report.results}
    assert by_key[warm_key].source == "dedup"
    assert by_key[warm_key].stages_run == 0
    assert sum(1 for r in report.results if r.source == "compiled") == \
        len(report.results) - 1
    # the journal never saw a compile for the deduped unit
    counts = store.journal(report.sweep_id).compile_counts()
    assert warm_key not in counts
    assert set(counts.values()) == {1}


def test_warm_sweep_is_all_dedup_with_zero_stages(store):
    cold = repro.sweep(LAYERS, VARIANTS, store=store)
    assert cold.counts()["compiled"] == len(cold.results)
    repro.clear_cache()
    warm = repro.sweep(LAYERS, VARIANTS, store=store)
    assert warm.counts()["dedup"] == len(warm.results)
    assert warm.stages_run() == 0
    assert warm.cycles_by_key() == cold.cycles_by_key()


# ---------------------------------------------------------------------------
# external workers: claims + stale-claim reclaim
# ---------------------------------------------------------------------------


def test_live_claim_is_respected_stale_claim_is_reclaimed(store):
    units = expand_plan(["DLRM-FC4"], ["hvx", "dnnweaver"])
    sid = plan_id(units)
    # another (live) worker holds unit 0: we must skip it
    # (drain_timeout=0: single pass — don't wait out the live claim)
    assert store.claim(sid, units[0].key, "other-worker")
    rep = run_external_worker(units, store, "me", sweep_id=sid,
                              stale_claim_timeout=600, drain_timeout=0)
    by_key = {r.key: r for r in rep.results}
    assert by_key[units[0].key].status == "skipped"
    assert by_key[units[1].key].status == "ok"
    # the holder crashed: its claim goes stale and is reclaimed
    claim = store._claim_path(sid, units[0].key)
    past = os.stat(claim).st_mtime - 3600
    os.utime(claim, (past, past))
    rep2 = run_external_worker(units, store, "me", sweep_id=sid,
                               stale_claim_timeout=60)
    by_key = {r.key: r for r in rep2.results}
    assert by_key[units[0].key].status == "ok"
    assert by_key[units[0].key].source == "compiled"
    assert store.stats["reclaims"] == 1
    # merged fleet view: every unit done exactly once
    merged = SweepReport.merge([rep, rep2])
    assert all(r.status == "ok" for r in merged.results)
    assert set(store.journal(sid).compile_counts().values()) == {1}


def test_claim_heartbeat_keeps_long_compiles_alive(tmp_path):
    """A held claim is refreshed while its unit compiles, so a slow unit
    is never mistaken for a crashed worker's and double-compiled."""
    import time
    path = tmp_path / "unit.claim"
    path.write_text("{}")
    with sweep_mod._ClaimHeartbeat(str(path), interval=0.05):
        past = os.stat(path).st_mtime - 3600
        os.utime(path, (past, past))        # simulate ageing toward stale
        time.sleep(0.3)                     # ... but the heartbeat beats
        assert time.time() - os.stat(path).st_mtime < 10
    # once the worker stops (crash/exit), the claim ages out normally
    past = os.stat(path).st_mtime - 3600
    os.utime(path, (past, past))
    time.sleep(0.15)
    assert time.time() - os.stat(path).st_mtime >= 3600 - 60


def test_survivor_drains_units_of_a_worker_that_crashed_mid_claim(store):
    """The last live worker must not walk past a held claim and exit: it
    re-visits held units until the holder finishes (store hit) or its
    claim goes stale — here the 'holder' is dead from the start, so the
    survivor waits out the stale timeout and reclaims."""
    units = expand_plan(["DLRM-FC4"], ["hvx"])
    sid = plan_id(units)
    assert store.claim(sid, units[0].key, "crashed-worker")
    rep = run_external_worker(units, store, "survivor", sweep_id=sid,
                              stale_claim_timeout=1.0, drain_timeout=30)
    by_key = {r.key: r for r in rep.results}
    assert by_key[units[0].key].status == "ok"       # drained, not skipped
    assert by_key[units[0].key].source == "compiled"
    assert store.stats["reclaims"] == 1


def test_two_external_workers_drain_the_plan_without_double_work(store):
    units = expand_plan(LAYERS, VARIANTS[:1])
    sid = plan_id(units)
    reps = [run_external_worker(units, store, w, sweep_id=sid)
            for w in ("w-a", "w-b")]
    merged = SweepReport.merge(reps)
    assert merged.counts()["ok"] == len(units)
    counts = store.journal(sid).compile_counts()
    assert len(counts) == len(units) and set(counts.values()) == {1}


# ---------------------------------------------------------------------------
# process backend + compile_many(parallel=)
# ---------------------------------------------------------------------------


def test_process_backend_compiles_each_unit_exactly_once(store):
    report = repro.sweep(LAYERS, VARIANTS, workers=2, store=store)
    c = report.counts()
    assert c["ok"] == len(LAYERS) * len(VARIANTS)
    assert c["compiled"] == c["ok"]
    assert {r.worker for r in report.results} == {"w0", "w1"}
    counts = store.journal(report.sweep_id).compile_counts()
    assert len(counts) == c["ok"] and set(counts.values()) == {1}
    # warm re-run: nothing dispatched, zero stages, same cycles
    warm = repro.sweep(LAYERS, VARIANTS, workers=2, store=store)
    assert warm.counts()["dedup"] == c["ok"]
    assert warm.stages_run() == 0
    assert warm.cycles_by_key() == report.cycles_by_key()


def test_compile_many_parallel_matches_sequential(store):
    pairs = [(layer, v) for layer in LAYERS for v in VARIANTS]
    opts = repro.CompileOptions(store=store)
    arts = repro.compile_many(pairs, options=opts, parallel=2)
    # workers prefilled the store; the ordered pass restored warm
    assert all(a.ctx.executed == [] for a in arts)
    assert repro.cache_stats()["store_hits"] == len(pairs)
    parallel_cycles = [a.cycles() for a in arts]
    repro.clear_cache()
    sequential = [a.cycles() for a in repro.compile_many(pairs)]
    assert parallel_cycles == sequential


def test_compile_many_parallel_without_store_warns_and_falls_back():
    repro.clear_cache()
    with pytest.warns(UserWarning, match="parallel"):
        arts = repro.compile_many(["DLRM-FC4"], parallel=2)
    assert arts[0].cycles() > 0
    repro.clear_cache()


# ---------------------------------------------------------------------------
# the CLI (python -m repro.sweep) — what the sweep-parallel CI job runs
# ---------------------------------------------------------------------------


def _run_cli(tmp_path, *extra):
    env = dict(os.environ, PYTHONPATH="src",
               REPRO_CACHE_DIR=str(tmp_path / "store"))
    return subprocess.run(
        [sys.executable, "-m", "repro.sweep",
         "--layers", ",".join(LAYERS), "--targets", ",".join(VARIANTS),
         "--workers", "2", *extra],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600)


def test_cli_cold_then_warm_enforces_ci_contract(tmp_path):
    cold = _run_cli(tmp_path, "--assert-unique-compiles")
    assert cold.returncode == 0, cold.stdout + cold.stderr
    assert "compiled exactly once" in cold.stdout
    warm = _run_cli(tmp_path, "--assert-unique-compiles",
                    "--expect-store-hits")
    assert warm.returncode == 0, warm.stdout + warm.stderr
    assert "zero pipeline stages executed" in warm.stdout


def test_cli_expect_store_hits_fails_cold(tmp_path):
    r = _run_cli(tmp_path, "--expect-store-hits")
    assert r.returncode == 1
    assert "FAIL" in r.stderr


# ---------------------------------------------------------------------------
# PR 5: strategy racing + cross-backend determinism
# ---------------------------------------------------------------------------

RACE_SEARCHES = [
    repro.SearchOptions(strategy="beam", generations=2, population=6,
                        seed=0, max_candidates=128),
    repro.SearchOptions(strategy="evolutionary", generations=2,
                        population=6, seed=0, max_candidates=128),
]


@pytest.mark.search
def test_race_pins_winner_per_layer_and_journals_exactly_once(store):
    layers = ["DLRM-FC3", "DLRM-FC4"]
    report = repro.sweep(layers, ["hvx"], store=store,
                         searches=RACE_SEARCHES, race=True)
    assert report.counts()["ok"] == 4          # 2 layers x 2 strategies
    assert len(report.pins) == len(layers)
    counts = store.journal(report.sweep_id).compile_counts()
    assert len(counts) == 4                    # one per (layer, strategy)
    assert set(counts.values()) == {1}         # ...compiled exactly once
    by_layer = {r.layer: [] for r in report.ok}
    for r in report.ok:
        by_layer[r.layer].append(r)
    for pin in report.pins:
        assert pin["cycles"] == min(r.cycles for r in by_layer[pin["layer"]])
        assert pin["strategy"] in ("beam", "evolutionary")
        assert sorted(pin["raced"]) == pin["raced"] and len(pin["raced"]) == 2
        assert store.load_pin(store.pin_name(pin["layer"], "hvx")) is not None
    assert "winner" in report.race_table()

    # a warm re-race changes nothing: all dedup, same winners, still once
    warm = repro.sweep(layers, ["hvx"], store=store,
                       searches=RACE_SEARCHES, race=True)
    assert warm.counts()["dedup"] == 4
    assert [p["key"] for p in warm.pins] == [p["key"] for p in report.pins]
    counts = store.journal(report.sweep_id).compile_counts()
    assert set(counts.values()) == {1}


def test_race_requires_store_and_two_strategies(store):
    with pytest.raises(ValueError, match="ArtifactStore"):
        repro.sweep(["DLRM-FC4"], ["hvx"], searches=RACE_SEARCHES,
                    race=True, store=None)
    with pytest.raises(ValueError, match="two"):
        repro.sweep(["DLRM-FC4"], ["hvx"], store=store, race=True,
                    searches=[RACE_SEARCHES[0]])


def test_search_options_json_roundtrip_with_pr5_fields():
    from repro.core.sweep import options_from_json, options_to_json
    sopts = repro.SearchOptions(strategy="beam", beam_width=5,
                                warm_start=True, patience=3)
    opts = repro.CompileOptions(search=sopts)
    rt = options_from_json(json.loads(json.dumps(options_to_json(opts))))
    assert rt.search == sopts
    assert rt.fingerprint() == opts.fingerprint()


@pytest.mark.search
def test_search_traces_byte_identical_across_fork_and_spawn(tmp_path):
    """Same plan, same seed, different worker start methods: the stored
    search digests (trace, winner, cycles) must be byte-identical — the
    determinism contract across sweep backends."""
    import multiprocessing as mp

    methods = [m for m in ("fork", "spawn")
               if m in mp.get_all_start_methods()]
    if len(methods) < 2:
        pytest.skip("platform offers a single mp start method")
    digests = {}
    for method in methods:
        repro.clear_cache()
        st = ArtifactStore(str(tmp_path / method))
        report = repro.sweep(["DLRM-FC4"], ["hvx"], store=st, workers=2,
                             searches=RACE_SEARCHES, backend="process",
                             mp_start=method)
        assert report.counts()["ok"] == 2, report.summary()
        entries = {}
        for r in report.ok:
            s = (st.peek(r.key) or {}).get("search")
            assert s is not None
            entries[r.key] = json.dumps(s, sort_keys=True)
        digests[method] = entries
    assert digests[methods[0]] == digests[methods[1]]
    repro.clear_cache()


@pytest.mark.search
def test_cli_race_prints_winners_and_asserts_unique(tmp_path):
    env = dict(os.environ, PYTHONPATH="src",
               REPRO_CACHE_DIR=str(tmp_path / "store"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.sweep",
         "--layers", "DLRM-FC4", "--targets", "hvx",
         "--search", "strategy=beam,generations=2,population=6,seed=0,"
                     "max_candidates=128",
         "--search", "strategy=evolutionary,generations=2,population=6,"
                     "seed=0,max_candidates=128",
         "--race", "--assert-unique-compiles"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600)
    assert r.returncode == 0, r.stdout + r.stderr
    assert "winner" in r.stdout
    assert "compiled exactly once" in r.stdout


def test_cli_race_needs_two_searches(tmp_path):
    env = dict(os.environ, PYTHONPATH="src",
               REPRO_CACHE_DIR=str(tmp_path / "store"))
    r = subprocess.run(
        [sys.executable, "-m", "repro.sweep", "--layers", "DLRM-FC4",
         "--targets", "hvx", "--search", "beam", "--race"],
        capture_output=True, text=True, env=env, cwd=ROOT, timeout=600)
    assert r.returncode == 2
    assert "two" in r.stderr


def test_race_pins_survivor_when_rival_strategy_fails(store):
    """A rival strategy's unit failing must not cost the (layer, target)
    its pin: the surviving strategy's best result is pinned."""
    import dataclasses

    from repro.core.sweep import _pin_race_winners

    units = expand_plan(["DLRM-FC4"], ["hvx"], searches=RACE_SEARCHES)
    ok_unit, failed_unit = units
    art = repro.compile("DLRM-FC4", "hvx",
                        dataclasses.replace(ok_unit.options, store=store))
    report = SweepReport(sweep_id="x", results=[
        UnitResult(key=ok_unit.key, layer="DLRM-FC4", target="hvx",
                   opt=ok_unit.opt, status="ok", source="compiled",
                   cycles=art.cycles()),
        UnitResult(key=failed_unit.key, layer="DLRM-FC4", target="hvx",
                   opt=failed_unit.opt, status="failed", error="boom"),
    ])
    pins = _pin_race_winners(units, report, store, None)
    assert len(pins) == 1
    assert pins[0]["key"] == ok_unit.key


def test_cli_rejects_malformed_search_spec(tmp_path):
    env = dict(os.environ, PYTHONPATH="src",
               REPRO_CACHE_DIR=str(tmp_path / "store"))
    for bad in ("bem", "generations=lots"):
        r = subprocess.run(
            [sys.executable, "-m", "repro.sweep", "--layers", "DLRM-FC4",
             "--targets", "hvx", "--search", bad],
            capture_output=True, text=True, env=env, cwd=ROOT, timeout=600)
        assert r.returncode == 2, (bad, r.stdout, r.stderr)
        assert "error: --search" in r.stderr
        assert "Traceback" not in r.stderr
