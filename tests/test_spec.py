"""Declarative covenant specs: ACG round-trip identity, the
string-addressable target registry (incl. derived variants), and covenant
validation diagnostics (named errors, not tracebacks)."""
import dataclasses

import pytest

import repro
from repro.core import library, targets
from repro.core.acg import ACG
from repro.core.codelet import Codelet, Compute, Loop, ref, v
from repro.core.covenant import (CovenantError, check_covenant, validate_acg)
from repro.core.dtypes import dt
from repro.core.spec import (ACGSpec, SpecError, acg_spec, parse_overrides,
                             scap, scu, sedge, smem, sop, validate_spec)

EVAL_TARGETS = ("hvx", "dnnweaver")
# small enough to expand the full mnemonic stream
STREAM_LAYERS = ("DLRM-FC2", "DLRM-FC3", "DLRM-FC4")


# ---------------------------------------------------------------------------
# round-trip identity
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("name", sorted(targets.BUNDLED_SPECS))
def test_spec_roundtrip_fingerprint_identity(name):
    """from_spec(to_spec(acg)) is fingerprint-identical, and the bundled
    spec *is* that canonical form."""
    spec = targets.BUNDLED_SPECS[name]
    acg = ACG.from_spec(spec)
    assert acg.to_spec() == spec
    assert acg.to_spec().fingerprint() == spec.fingerprint()
    again = ACG.from_spec(acg.to_spec())
    assert again.describe() == acg.describe()
    assert again.to_spec().fingerprint() == spec.fingerprint()


@pytest.mark.parametrize("name", sorted(targets.BUNDLED_SPECS))
def test_spec_json_roundtrip(name):
    spec = targets.BUNDLED_SPECS[name]
    again = ACGSpec.from_json(spec.to_json())
    assert again == spec
    assert again.fingerprint() == spec.fingerprint()


@pytest.mark.parametrize("target", EVAL_TARGETS)
def test_roundtrip_equal_compiles_every_paper_layer(target):
    """Every paper layer compiles to the same content-addressed key (hence
    the same schedule and analytics) on the round-tripped ACG."""
    base = targets.get_target(target)
    rt = ACG.from_spec(base.to_spec())
    for spec in library.PAPER_LAYERS:
        a = repro.compile(spec, base)
        b = repro.compile(spec, rt)
        assert b is a, spec.key  # same key => same cached artifact
        assert b.cycles() == a.cycles()


@pytest.mark.parametrize("target", EVAL_TARGETS)
@pytest.mark.parametrize("layer", STREAM_LAYERS)
def test_roundtrip_byte_identical_streams(target, layer):
    """Unrollable layers produce byte-identical mnemonic streams on the
    original and the round-tripped ACG."""
    a = repro.compile(layer, targets.get_target(target), cache=False)
    b = repro.compile(
        layer, ACG.from_spec(targets.get_target(target).to_spec()),
        cache=False)
    assert [m.encode() for m in a.program.mnemonics] == \
        [m.encode() for m in b.program.mnemonics]
    assert [str(m) for m in a.program.mnemonics] == \
        [str(m) for m in b.program.mnemonics]


# ---------------------------------------------------------------------------
# registry resolution
# ---------------------------------------------------------------------------


def test_spec_target_with_unhashable_attrs_compiles():
    """Mnemonic attrs may hold list values after a JSON round-trip; the
    driver's spec memo must not require the spec to be hashable."""
    spec = ACGSpec.from_json(targets.HVX_SPEC.to_json())
    from repro.core.spec import MnemonicSpec
    spec = dataclasses.replace(
        spec, name="hvx_attrs",
        mnemonics=spec.mnemonics + (
            MnemonicSpec("HALT", 0x7F, (), attrs=(("units", ["CORE"]),)),))
    with pytest.raises(TypeError):
        hash(spec)  # the precondition that used to crash the memo
    art = repro.compile("DLRM-FC4", spec)
    assert art.cycles() > 0


def test_pe_derive_only_rescales_the_pe_grid_unit():
    """pe= sweeps one design axis: the unit owning the largest matmul
    geometry (the systolic array).  The SIMD unit — whose lane count
    happens to equal the array width — keeps all its shapes."""
    d = targets.DNNWEAVER_SPEC.derive(pe="32x32")
    systolic = next(c for c in d.computes if c.name == "SYSTOLIC")
    simd = next(c for c in d.computes if c.name == "SIMD")
    gemm = next(k for k in systolic.capabilities if k.name == "GEMM")
    assert gemm.geometry == (1, 32, 32)
    assert gemm.inputs[1] == ("i8", 32, 32)
    add = next(k for k in simd.capabilities if k.name == "ADD")
    assert add.outputs[0] == ("i32", 64)  # lanes untouched
    mac = next(k for k in simd.capabilities if k.name == "MAC")
    assert mac.geometry == (1, 64, 1)     # SIMD MAC untouched too


def test_registered_derived_spec_resolves_by_its_at_name():
    """A registered spec whose *name* contains '@' must resolve exactly,
    not be re-parsed as base@overrides against an unknown base."""
    npu = targets.DNNWEAVER_SPEC.derive(pe="16x16", name="solo16@custom")
    repro.targets.register(npu)
    try:
        assert targets.get_spec("solo16@custom") == npu
        art = repro.compile("DLRM-FC4", "solo16@custom")
        assert art.target == "solo16@custom"
    finally:
        targets.TARGETS.pop("solo16@custom", None)


def test_exact_registration_shadows_variant_derivation_in_driver():
    """Registering a spec under an exact '@' name must invalidate the
    driver's memo for that name, even though the base factory is
    unchanged — the registered entry wins from then on."""
    name = "dnnweaver@pe=32x32"
    derived = repro.compile("DLRM-FC4", name)   # on-the-fly variant
    custom = targets.HVX_SPEC.derive(name=name)  # same name, hvx content
    repro.targets.register(custom)
    try:
        registered = repro.compile("DLRM-FC4", name)
        assert registered.key != derived.key
        assert registered.acg.to_spec().fingerprint() == custom.fingerprint()
    finally:
        targets.TARGETS.pop(name, None)
    # with the registration gone, the variant derivation is back
    again = repro.compile("DLRM-FC4", name)
    assert again.key == derived.key


def test_fingerprint_canonical_regardless_of_construction_order():
    """attrs / operand_ports ordering is canonicalized at fingerprint
    time, so a spec built with unsorted fields round-trips to the same
    identity (and the driver's spec memo actually hits)."""
    from repro.core.spec import MnemonicSpec

    def with_attrs(attrs):
        return dataclasses.replace(
            targets.HVX_SPEC, name="hvx_a",
            mnemonics=targets.HVX_SPEC.mnemonics + (
                MnemonicSpec("HALT", 0x7F, (), attrs=attrs),))

    a = with_attrs((("zeta", 1), ("alpha", 2)))
    b = with_attrs((("alpha", 2), ("zeta", 1)))
    assert a.fingerprint() == b.fingerprint()
    assert ACG.from_spec(a).to_spec().fingerprint() == a.fingerprint()


def test_registry_resolution_names_and_specs():
    by_name = repro.compile("DLRM-FC4", "hvx")
    by_spec = repro.compile("DLRM-FC4", targets.HVX_SPEC)
    by_acg = repro.compile("DLRM-FC4", targets.get_target("hvx"))
    assert by_name is by_spec is by_acg


def test_registry_unknown_target_names_known():
    with pytest.raises(KeyError, match="unknown target 'nonesuch'"):
        targets.get_target("nonesuch")
    with pytest.raises(KeyError, match="unknown target 'nonesuch'"):
        repro.compile("DLRM-FC4", "nonesuch@pe=8x8")


def test_register_spec_roundtrips_through_driver():
    npu = acg_spec(
        "test_npu",
        memories=[smem("DRAM", 8, 1, 1 << 24, offchip=True),
                  smem("SPM", 32, 16, 4096)],
        computes=[scu("PE", [
            scap("GEMM", sop("i32", 8),
                 [sop("i8", 8), sop("i8", 8, 8), sop("i32", 8)],
                 geometry=(1, 8, 8)),
            scap("MAC", sop("i32", 8),
                 [sop("i8", 8), sop("i8", 8, 8), sop("i32", 8)],
                 geometry=(1, 8, 8)),
        ], slot="pe")],
        edges=[sedge("DRAM", "SPM", 128, bidir=True),
               sedge("SPM", "PE", 256, bidir=True)],
    )
    repro.targets.register(npu)
    try:
        assert "test_npu" in repro.targets.list()
        art = repro.compile("DLRM-FC4", "test_npu")
        assert art.cycles() > 0
        variant = repro.compile("DLRM-FC4", "test_npu@pe=4x4")
        assert variant.key != art.key
    finally:
        targets.TARGETS.pop("test_npu", None)


def test_get_spec_of_factory_registered_target():
    """Targets registered as plain factories (legacy register_target) are
    snapshotted to specs on demand — variants derive from the snapshot."""
    repro.register_target("hvx_twin", targets.hvx_acg)
    try:
        assert targets.get_spec("hvx_twin") == targets.HVX_SPEC
        acg = targets.get_target("hvx_twin@issue_slots=1")
        assert acg.issue_slots == 1
    finally:
        targets.TARGETS.pop("hvx_twin", None)


# ---------------------------------------------------------------------------
# derived variants
# ---------------------------------------------------------------------------


def test_derive_canonical_names_merge_and_parse():
    base = targets.DNNWEAVER_SPEC
    d1 = base.derive(pe="32x32")
    assert d1.name == "dnnweaver@pe=32x32"
    d2 = d1.derive(memories={"VMEM1": {"depth": 4096}})
    assert d2.name == "dnnweaver@VMEM1.depth=4096,pe=32x32"
    # the canonical name parses back to the same spec
    assert targets.get_spec(d2.name) == d2
    # and overrides-merge is idempotent for repeated keys
    assert d1.derive(pe="32x32") == d1


def test_derived_variant_distinct_key_and_cost():
    """Acceptance: a derived variant produces a distinct store key and a
    distinct cost report from its base."""
    base = repro.compile("DLRM-FC1", "dnnweaver")
    variant = repro.compile("DLRM-FC1", "dnnweaver@pe=32x32")
    assert variant.key != base.key
    assert variant.cycles() != base.cycles()
    assert variant.target == "dnnweaver@pe=32x32"


def test_derive_rejects_unknown_entities():
    with pytest.raises(SpecError, match="no memory node 'NOPE'"):
        targets.HVX_SPEC.derive(memories={"NOPE": {"depth": 1}})
    with pytest.raises(SpecError, match="unknown field"):
        targets.HVX_SPEC.derive(memories={"VRF": {"color": 1}})
    with pytest.raises(SpecError, match="no edge"):
        targets.HVX_SPEC.derive(edges={("VRF", "GRF"): {"bandwidth": 1}})
    with pytest.raises(SpecError):
        targets.HVX_SPEC.derive(pe="3x4")  # non-square


def test_parse_overrides_grammar():
    kw = parse_overrides("pe=16x16,issue_slots=2,VRF.depth=64,"
                         "edge.L2.VRF.bandwidth=512")
    assert kw == {"pe": "16x16", "issue_slots": 2,
                  "memories": {"VRF": {"depth": 64}},
                  "edges": {("L2", "VRF"): {"bandwidth": 512}}}
    with pytest.raises(SpecError, match="not 'key=value'"):
        parse_overrides("pe")
    with pytest.raises(SpecError, match="unknown override key"):
        parse_overrides("warp=9")
    with pytest.raises(SpecError, match="must be an integer"):
        parse_overrides("issue_slots=abc")
    with pytest.raises(SpecError, match="must be an integer"):
        parse_overrides("VRF.depth=big")
    with pytest.raises(SpecError, match="look like '32x32'"):
        targets.HVX_SPEC.derive(pe="axb")
    assert parse_overrides("L2.offchip=1") == \
        {"memories": {"L2": {"offchip": True}}}
    assert parse_overrides("L2.offchip=false") == \
        {"memories": {"L2": {"offchip": False}}}
    with pytest.raises(SpecError, match="must be a boolean"):
        parse_overrides("L2.offchip=yes")


def test_compile_many_heterogeneous_pairs():
    """One batched sweep across architecture variants via (codelet, target)
    pairs."""
    repro.clear_cache()
    arts = repro.compile_many(
        [("DLRM-FC4", "dnnweaver"),
         ("DLRM-FC4", "dnnweaver@pe=32x32"),
         "DLRM-FC4"],                        # falls back to sweep target
        target="hvx")
    assert [a.target for a in arts] == \
        ["dnnweaver", "dnnweaver@pe=32x32", "hvx"]
    assert len({a.key for a in arts}) == 3
    # and pair items hit the same cache entries as direct compiles
    assert repro.compile("DLRM-FC4", "dnnweaver@pe=32x32") is arts[1]


# ---------------------------------------------------------------------------
# spec validation
# ---------------------------------------------------------------------------


def test_validate_spec_names_every_problem():
    bad = acg_spec(
        "bad",
        memories=[smem("M", 8, 1, 0), smem("M", 8, 1, 64)],  # dup + depth=0
        computes=[scu("CU", [scap("GEMM", sop("q8", 4), [sop("i8", 4)])])],
        edges=[sedge("M", "GHOST", 0)],
    )
    problems = validate_spec(bad, raise_on_error=False)
    text = "\n".join(problems)
    assert "duplicate node name(s): ['M']" in text
    assert "depth must be positive" in text
    assert "unknown dtype 'q8'" in text
    assert "unknown node 'GHOST'" in text
    assert "bandwidth must be positive" in text
    with pytest.raises(SpecError, match="invalid covenant spec 'bad'"):
        validate_spec(bad)


def test_validate_spec_names_bad_dimension_types():
    """Hand-authored JSON with string dims must get a named problem, not a
    TypeError from the comparison."""
    d = targets.EXAMPLE_SPEC.to_dict()
    d["computes"][0]["capabilities"][0]["outputs"][0] = ["i16", "1"]
    problems = validate_spec(ACGSpec.from_dict(d), raise_on_error=False)
    assert any("non-positive or non-integer dimension" in p
               for p in problems)


def test_scap_promotes_bare_operands_on_both_sides():
    k = scap("RELU", sop("i16", 1), sop("i16", 1))
    assert k.outputs == (("i16", 1),) and k.inputs == (("i16", 1),)


def test_register_spec_alias_renames_for_variant_resolution():
    """Registering under an alias renames the spec so canonical derived
    names ('alias@k=v') resolve."""
    spec = targets.get_spec("hvx").derive(name="mychip")
    registered = repro.targets.register(spec, name="alias_chip")
    try:
        assert registered.name == "alias_chip"
        v = targets.get_spec("alias_chip@issue_slots=1")
        assert v.name == "alias_chip@issue_slots=1"
        assert targets.get_target(v.name).issue_slots == 1
    finally:
        targets.TARGETS.pop("alias_chip", None)


def test_validate_spec_mnemonic_checks():
    from repro.core.spec import FieldSpec, MnemonicSpec
    spec = dataclasses.replace(
        targets.HVX_SPEC,
        mnemonics=targets.HVX_SPEC.mnemonics + (
            MnemonicSpec("XFER", 0x40, ()),              # duplicate name
            MnemonicSpec("TINY", 0x01, (                  # opcode collision
                FieldSpec("E", 1, ("a", "b", "c")),)),    # enum overflow
        ))
    problems = validate_spec(spec, raise_on_error=False)
    text = "\n".join(problems)
    assert "duplicate mnemonic 'XFER'" in text
    assert "collides" in text
    assert "enumerates 3 values in 1 bits" in text


def test_validate_acg_reachability():
    g = ACG("island")
    g.add_memory("M", 32, 1, 64, offchip=True)
    g.add_compute("CU", [scap_obj()])
    problems = validate_acg(g, raise_on_error=False)
    assert any("connected to no edge" in p for p in problems)
    assert any("unreachable from the operand home" in p for p in problems)


def scap_obj():
    from repro.core.acg import cap, ospec
    return cap("ADD", ospec("i32", 4), [ospec("i32", 4)] * 2)


def test_validate_bundled_reports_instead_of_crashing():
    """The CI reporter must emit FAIL lines for a broken bundled spec and
    keep going, never traceback on the first problem."""
    import repro.targets as facade

    broken = dataclasses.replace(targets.HVX_SPEC, issue_slots=0)
    facade.BUNDLED_SPECS["aa_broken"] = broken
    targets.TARGETS["aa_broken"] = lambda: ACG.from_spec(broken)
    lines = []
    try:
        problems = facade.validate_bundled(sweep=False, emit=lines.append)
    finally:
        facade.BUNDLED_SPECS.pop("aa_broken", None)
        targets.TARGETS.pop("aa_broken", None)
    assert problems >= 1
    assert any(l.startswith("FAIL aa_broken") and "issue_slots" in l
               for l in lines)
    assert any(l.startswith("ok   hvx") for l in lines)  # kept going


# ---------------------------------------------------------------------------
# covenant diagnostics: named errors, not deep KeyErrors
# ---------------------------------------------------------------------------


def _codelet_with_capability(capname: str) -> Codelet:
    c = Codelet(f"uses_{capname.lower()}")
    x = c.inp("x", [8], "i32")
    o = c.out("y", [8], "i32")
    op = Compute(capname, ref(o, v("n")), (ref(x, v("n")),),
                 roles={"n": ["n"]}, dtype=dt("i32"))
    c.body.append(Loop("n", 0, 8, 1, [op]))
    return c


def test_unknown_capability_is_named():
    with pytest.raises(CovenantError) as ei:
        repro.compile(_codelet_with_capability("FFT"), "hvx", cache=False)
    err = ei.value
    assert err.cdlt_name == "uses_fft" and err.acg_name == "hvx"
    (viol,) = err.violations
    assert viol.kind == "capability" and viol.subject == "FFT"
    assert "no compute node" in viol.message
    assert "GEMM" in viol.hint  # lists what the target does support


def test_missing_mnemonic_is_named():
    spec = dataclasses.replace(
        targets.HVX_SPEC, name="hvx_nomac",
        mnemonics=tuple(m for m in targets.HVX_SPEC.mnemonics
                        if m.name != "MAC"))
    with pytest.raises(CovenantError) as ei:
        repro.compile(library.gemm(8, 16, 12, in_dtype="u8"),
                      ACG.from_spec(spec), cache=False)
    viols = ei.value.violations
    assert any(v.kind == "mnemonic" and v.subject == "MAC" for v in viols)


def test_missing_transfer_mnemonic_is_named():
    spec = dataclasses.replace(
        targets.HVX_SPEC, name="hvx_noxfer",
        mnemonics=tuple(m for m in targets.HVX_SPEC.mnemonics
                        if m.name != "XFER"))
    viols = check_covenant(library.gemm(4, 8, 4, in_dtype="u8"),
                           ACG.from_spec(spec), raise_on_error=False)
    assert any(v.kind == "mnemonic" and v.subject == "XFER" for v in viols)


def test_undersized_memory_is_named():
    tiny = targets.HVX_SPEC.derive(
        name="hvx_tinyvrf",
        memories={"VRF": {"data_width": 8, "banks": 1, "depth": 16}})
    with pytest.raises(CovenantError) as ei:
        repro.compile(library.gemm(8, 16, 12, in_dtype="u8"),
                      ACG.from_spec(tiny), cache=False)
    viols = [v for v in ei.value.violations if v.kind == "memory"]
    assert viols and viols[0].subject == "VRF"
    assert "cannot hold one" in viols[0].message
    assert "grow VRF" in viols[0].hint


def test_covenant_clean_on_every_bundled_target():
    for name in targets.BUNDLED_SPECS:
        acg = targets.get_target(name)
        assert validate_acg(acg, raise_on_error=False) == []
        assert check_covenant(library.gemm(8, 16, 12, in_dtype="u8"), acg,
                              raise_on_error=False) == []


def test_covenant_check_can_be_disabled():
    """check_covenant=False restores the old late-failure behaviour (and a
    distinct cache key), for callers who want raw pipeline errors."""
    with pytest.raises(ValueError) as ei:
        repro.compile(_codelet_with_capability("FFT"), "hvx",
                      repro.CompileOptions(check_covenant=False),
                      cache=False)
    assert not isinstance(ei.value, CovenantError)  # the deep error again
    art = repro.compile(_codelet_with_capability("ADD"), "hvx",
                        repro.CompileOptions(check_covenant=False),
                        cache=False)
    assert art.cycles() > 0
