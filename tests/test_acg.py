"""ACG structure: node/edge semantics, capability lookup, mnemonic encoding."""
import pytest

from repro.core import targets
from repro.core.acg import ACG, Mnemonic, cap, efield, ifield, ospec
from repro.core.dtypes import dt


@pytest.mark.parametrize("name", sorted(targets.TARGETS))
def test_targets_construct(name):
    g = targets.get_target(name)
    assert g.memory_nodes() and g.compute_nodes()
    assert g.describe()


def test_memory_attributes_match_paper_example():
    g = targets.example_acg()
    gsp = g.memory("GSP")
    # §2.1.1: 32 x 7 = 224-bit entries; 224 x 1024 = 229,376 bits = 28,672 B
    assert gsp.elem_bits == 224
    assert gsp.capacity_bits == 229_376
    assert gsp.capacity_bytes == 28_672


def test_dnnweaver_table3_attributes():
    g = targets.dnnweaver_acg()
    assert g.memory("WBUF").banks == 4096
    assert g.memory("IBUF").data_width == 8
    sy = g.compute("SYSTOLIC")
    gemms = sy.find("GEMM", dt("i32"))
    assert gemms and gemms[0].geometry == (1, 64, 64)
    # OBUF -> DRAM unidirectional; no DRAM -> OBUF edge
    assert g.edge("OBUF", "DRAM")
    with pytest.raises(KeyError):
        g.edge("DRAM", "OBUF")


def test_hvx_has_no_dram_node():
    # §5.1.1: HVX DRAM is hardware-managed, hence absent from the ACG
    g = targets.hvx_acg()
    assert "DRAM" not in g.nodes
    assert g.issue_slots == 4  # VLIW


def test_supporting_nodes_sorted_by_granularity():
    g = targets.example_acg()
    nodes = g.supporting_nodes("ADD", dt("i16"))
    grans = [c.out_elems for _, c in nodes]
    assert grans == sorted(grans, reverse=True)
    assert nodes[0][0].name == "VECTOR"  # 2-wide beats scalar


def test_highest_memory_is_offchip_home():
    g = targets.example_acg()
    assert g.highest_memory().name == "DRAM"
    g2 = targets.hvx_acg()
    assert g2.highest_memory().name == "L2"


def test_shortest_path_respects_direction():
    g = targets.dnnweaver_acg()
    p = g.shortest_path("DRAM", "SYSTOLIC")
    assert p[0] == "DRAM" and p[-1] == "SYSTOLIC"
    # the output path must leave through OBUF
    p2 = g.shortest_path("SYSTOLIC", "DRAM")
    assert "OBUF" in p2


def test_edge_transfer_ops():
    g = targets.example_acg()
    e = g.edge("DRAM", "GSP")
    assert e.transfer_ops(224) == 1
    assert e.transfer_ops(225) == 2
    assert e.transfer_ops(1) == 1


def test_mnemonic_field_encoding_roundtrip():
    g = targets.example_acg()
    mdef = g.mnemonics["ADD"]
    m = Mnemonic(mdef, {"SRC1_ADDR": 12, "SRC2_ADDR": 40, "DST_ADDR": 64,
                        "N": 2, "TGT": "VECTOR"})
    word = m.encode()
    assert isinstance(word, int) and word > 0
    # decode by shifting back out
    fields = list(mdef.fields)
    vals = {}
    for f in reversed(fields):
        vals[f.name] = word & ((1 << f.bits) - 1)
        word >>= f.bits
    assert word == mdef.opcode
    assert vals["SRC1_ADDR"] == 12 and vals["N"] == 2
    assert mdef.field("TGT").enum[vals["TGT"]] == "VECTOR"


def test_mnemonic_field_overflow_rejected():
    g = targets.example_acg()
    mdef = g.mnemonics["ADD"]
    m = Mnemonic(mdef, {"SRC1_ADDR": 1 << 40, "SRC2_ADDR": 0, "DST_ADDR": 0,
                        "N": 1, "TGT": "SCALAR"})
    with pytest.raises(ValueError):
        m.encode()


def test_duplicate_node_rejected():
    g = ACG("t")
    g.add_memory("M", 8, 1, 16)
    with pytest.raises(ValueError):
        g.add_memory("M", 8, 1, 16)


def test_capability_str_matches_paper_syntax():
    c = cap("ADD", ospec("i16", 2), [ospec("i16", 2), ospec("i16", 2)])
    assert str(c) == "(i16,2)=ADD((i16,2),(i16,2))"


def test_tpu_v5e_acg_mxu_alignment():
    g = targets.tpu_v5e_acg()
    mxu = g.compute("MXU")
    gemm = mxu.find("GEMM", dt("f32"))[0]
    assert gemm.geometry == (128, 128, 128)
    vmem = g.memory("VMEM")
    # addressable element = one (8,128) f32 tile = 4096 B
    assert vmem.elem_bits // 8 == 4096
    assert vmem.capacity_bytes == 128 * 2**20
