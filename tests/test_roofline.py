"""Roofline machinery: HLO cost parser (trip expansion), collective-bytes
parser, three-term model, analytic param counts."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.roofline import (PEAK_FLOPS, Roofline, model_flops, param_count,
                            roofline_terms)
from repro.roofline.hlo_cost import analyze


def _compiled(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_trip_expansion_exact():
    def body(c, _):
        return c @ c, None

    def f(x):
        y, _ = jax.lax.scan(body, x, None, length=10)
        return y

    c = _compiled(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    r = analyze(c.as_text())
    want = 10 * 2 * 128 ** 3
    assert abs(r["flops"] - want) / want < 1e-4


def test_nested_scan_expansion():
    def f(x):
        def outer(c, _):
            def inner(ci, _):
                return ci @ ci, None
            y, _ = jax.lax.scan(inner, c, None, length=5)
            return y, None
        y, _ = jax.lax.scan(outer, x, None, length=4)
        return y

    c = _compiled(f, jax.ShapeDtypeStruct((128, 128), jnp.float32))
    r = analyze(c.as_text())
    want = 20 * 2 * 128 ** 3
    assert abs(r["flops"] - want) / want < 1e-4


def test_remat_grad_expansion():
    def body(c, _):
        return c @ c, None

    def loss(x):
        y, _ = jax.lax.scan(jax.checkpoint(body), x, None, length=8)
        return jnp.sum(y)

    c = _compiled(jax.grad(loss), jax.ShapeDtypeStruct((128, 128),
                                                       jnp.float32))
    r = analyze(c.as_text())
    # fwd + recompute + bwd(2 dots per step) ~= 4x fwd for c@c (dc = dy@c^T
    # + c^T@dy); allow the range [3x, 5x]
    fwd = 8 * 2 * 128 ** 3
    assert 3 * fwd <= r["flops"] <= 5 * fwd


def test_flops_counts_batched_dot():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    c = _compiled(f, jax.ShapeDtypeStruct((4, 64, 32), jnp.float32),
                  jax.ShapeDtypeStruct((4, 32, 16), jnp.float32))
    r = analyze(c.as_text())
    want = 2 * 4 * 64 * 16 * 32
    assert abs(r["flops"] - want) / want < 0.05


def test_bytes_respect_vmem_threshold():
    # a tiny program's tensors all fit VMEM -> near-zero HBM bytes
    def f(a, b):
        return a + b

    c = _compiled(f, jax.ShapeDtypeStruct((64, 64), jnp.float32),
                  jax.ShapeDtypeStruct((64, 64), jnp.float32))
    assert analyze(c.as_text())["bytes"] == 0
    # a big tensor crosses the threshold
    c2 = _compiled(f, jax.ShapeDtypeStruct((2048, 2048), jnp.float32),
                   jax.ShapeDtypeStruct((2048, 2048), jnp.float32))
    assert analyze(c2.as_text())["bytes"] >= 3 * 2048 * 2048 * 4


def test_roofline_terms_and_bottleneck():
    rec = {"flops": PEAK_FLOPS, "bytes_accessed": 0.0,
           "collective_bytes": 0.0, "n_devices": 1}
    rl = roofline_terms(rec)
    assert rl.bottleneck == "compute"
    assert rl.compute_s == pytest.approx(1.0)
    assert rl.compute_fraction == pytest.approx(1.0)
    rec2 = dict(rec, collective_bytes=1e12)
    rl2 = roofline_terms(rec2)
    assert rl2.bottleneck == "collective"
    assert rl2.compute_fraction < 0.1


@pytest.mark.parametrize("arch,expected_b", [
    ("command-r-plus-104b", (95, 115)),
    ("gemma3-12b", (10, 14)),
    ("stablelm-12b", (11, 14)),
    ("qwen3-0.6b", (0.5, 0.9)),
    ("deepseek-moe-16b", (14, 20)),
    ("olmoe-1b-7b", (6, 8)),
    ("mamba2-2.7b", (2.4, 3.1)),
    # backbone only: the "3b" includes the ~400M SigLIP tower (a stub here)
    ("paligemma-3b", (1.7, 2.1)),
    ("zamba2-2.7b", (2.2, 3.2)),
    ("whisper-base", (0.05, 0.11)),
])
def test_param_counts_match_published(arch, expected_b):
    cfg = configs.get_config(arch)
    total, active = param_count(cfg)
    lo, hi = expected_b
    assert lo <= total / 1e9 <= hi, f"{arch}: {total / 1e9:.2f}B"
    assert active <= total


def test_moe_active_params_much_smaller():
    cfg = configs.get_config("deepseek-moe-16b")
    total, active = param_count(cfg)
    assert active < 0.35 * total  # 6+2 of 64 experts active


def test_model_flops_train_is_3x_forward_same_shape():
    cfg = configs.get_config("qwen3-0.6b")
    shape = configs.SHAPES["train_4k"]
    tr = model_flops(cfg, shape, "train")
    fw = model_flops(cfg, shape, "prefill")
    assert tr == pytest.approx(3 * fw, rel=1e-6)
