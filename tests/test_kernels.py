"""Pallas kernels vs ref.py oracles: shape/dtype sweeps (interpret=True)."""
import numpy as np
import pytest
import jax
import jax.numpy as jnp

from repro.kernels import ops, ref
from repro.kernels.tiling import attention_blocks, gemm_blocks

rng = np.random.default_rng(7)


def randn(*s, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(s), dtype)


# ---------------------------------------------------------------------------
# covenant tiler -> BlockSpec bridge
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mnk", [(512, 512, 512), (384, 4096, 1024),
                                 (8192, 8192, 8192), (100, 50, 30)])
def test_gemm_blocks_are_valid(mnk):
    m, n, k = mnk
    bm, bn, bk = gemm_blocks(m, n, k)
    assert bm >= 1 and bn >= 1 and bk >= 1
    # VMEM fit for the working set the kernel stages (a, b, acc blocks)
    bytes_ = (bm * bk + bk * bn) * 2 + bm * bn * 4
    assert bytes_ <= 128 * 2**20
    # MXU-friendly unless the problem is smaller than one tile
    if n >= 128:
        assert bn % 128 == 0
    if k >= 128:
        assert bk % 128 == 0


def test_attention_blocks_bounded():
    bq, bkv = attention_blocks(4096, 4096, 128)
    assert bq % 8 == 0 and bkv % 128 == 0
    assert bq * bkv <= 256 * 1024


# ---------------------------------------------------------------------------
# matmul
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("mnk", [(64, 64, 64), (96, 130, 200), (8, 8, 8),
                                 (33, 17, 9), (256, 128, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_matmul_float(mnk, dtype):
    m, n, k = mnk
    a = randn(m, k, dtype=dtype)
    b = randn(k, n, dtype=dtype)
    got = ops.covenant_matmul(a, b, blocks=(32, 128, 128))
    want = ref.matmul_ref(a, b)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32),
                               atol=5e-2 if dtype == jnp.bfloat16 else 1e-4,
                               rtol=1e-2 if dtype == jnp.bfloat16 else 1e-5)


@pytest.mark.parametrize("mnk", [(64, 64, 64), (40, 50, 60)])
def test_matmul_int8(mnk):
    m, n, k = mnk
    a = jnp.asarray(rng.integers(-8, 8, (m, k)), jnp.int8)
    b = jnp.asarray(rng.integers(-8, 8, (k, n)), jnp.int8)
    got = ops.covenant_matmul(a, b, blocks=(32, 128, 128))
    want = np.asarray(a, np.int32) @ np.asarray(b, np.int32)
    np.testing.assert_array_equal(np.asarray(got), want)


def test_matmul_covenant_default_blocks():
    a = randn(300, 200)
    b = randn(200, 150)
    got = ops.covenant_matmul(a, b)  # tiler-chosen blocks
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), atol=1e-3)


# ---------------------------------------------------------------------------
# flash attention
# ---------------------------------------------------------------------------

FA_CASES = [
    dict(b=2, hq=4, hkv=4, sq=64, sk=64, d=32, causal=True, win=None),
    dict(b=1, hq=8, hkv=2, sq=100, sk=100, d=16, causal=True, win=None),
    dict(b=2, hq=4, hkv=2, sq=64, sk=64, d=32, causal=True, win=16),
    dict(b=1, hq=4, hkv=4, sq=32, sk=96, d=32, causal=True, win=None),
    dict(b=1, hq=2, hkv=2, sq=48, sk=48, d=16, causal=False, win=None),
    dict(b=1, hq=4, hkv=1, sq=40, sk=40, d=64, causal=True, win=None),  # MQA
]


@pytest.mark.parametrize("case", FA_CASES)
def test_flash_attention_matches_ref(case):
    q = randn(case["b"], case["hq"], case["sq"], case["d"])
    k = randn(case["b"], case["hkv"], case["sk"], case["d"])
    v = randn(case["b"], case["hkv"], case["sk"], case["d"])
    got = ops.covenant_attention(q, k, v, causal=case["causal"],
                                 window=case["win"], blocks=(32, 128))
    want = ref.attention_ref(q, k, v, causal=case["causal"],
                             window=case["win"])
    np.testing.assert_allclose(got, want, atol=2e-3)


@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_dtypes(dtype):
    q = randn(1, 2, 64, 32, dtype=dtype)
    k = randn(1, 2, 64, 32, dtype=dtype)
    v = randn(1, 2, 64, 32, dtype=dtype)
    got = ops.covenant_attention(q, k, v, blocks=(32, 64))
    want = ref.attention_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(got, np.float32),
                               np.asarray(want, np.float32), atol=2e-2)


def test_flash_decode_matches_ref():
    b, hq, hkv, s, d = 3, 8, 2, 256, 32
    q = randn(b, hq, d)
    k = randn(b, hkv, s, d)
    v = randn(b, hkv, s, d)
    kv_len = jnp.asarray([100, 256, 17])
    got = ops.covenant_decode_attention(q, k, v, kv_len, block_kv=64)
    want = ref.attention_ref(q[:, :, None, :], k, v, causal=False,
                             kv_len=kv_len)[:, :, 0, :]
    np.testing.assert_allclose(got, want, atol=2e-3)


def test_flash_window_equals_dense_when_window_covers_all():
    q, k, v = randn(1, 2, 64, 16), randn(1, 2, 64, 16), randn(1, 2, 64, 16)
    wide = ops.covenant_attention(q, k, v, causal=True, window=4096,
                                  blocks=(32, 64))
    dense = ops.covenant_attention(q, k, v, causal=True, window=None,
                                   blocks=(32, 64))
    np.testing.assert_allclose(wide, dense, atol=1e-5)


# ---------------------------------------------------------------------------
# SSD scan
# ---------------------------------------------------------------------------

SSD_CASES = [
    dict(b=2, s=64, h=4, p=16, g=2, n=8, chunk=16),
    dict(b=1, s=100, h=4, p=8, g=4, n=16, chunk=32),
    dict(b=2, s=33, h=2, p=8, g=1, n=4, chunk=16),
    dict(b=1, s=16, h=2, p=4, g=2, n=4, chunk=16),  # single chunk
]


@pytest.mark.parametrize("case", SSD_CASES)
def test_ssd_matches_sequential_ref(case):
    b, s, h, p, g, n = (case[k] for k in "bshpgn")
    x = randn(b, s, h, p)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B = randn(b, s, g, n)
    C = randn(b, s, g, n)
    got, st = ops.covenant_ssd(x, dt, A, B, C, chunk=case["chunk"],
                               return_state=True)
    want, wst = ref.ssd_ref(x, dt, A, B, C, return_state=True)
    np.testing.assert_allclose(got, want, atol=2e-3)
    np.testing.assert_allclose(st, wst, atol=2e-3)


def test_ssd_init_state_continuation():
    """Splitting a sequence across two calls == one call (decode contract)."""
    b, s, h, p, g, n = 1, 64, 2, 8, 2, 8
    x = randn(b, s, h, p)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (b, s, h)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (h,)), jnp.float32)
    B, C = randn(b, s, g, n), randn(b, s, g, n)
    y_full, st_full = ops.covenant_ssd(x, dt, A, B, C, chunk=16,
                                       return_state=True)
    half = s // 2
    y1, st1 = ops.covenant_ssd(x[:, :half], dt[:, :half], A, B[:, :half],
                               C[:, :half], chunk=16, return_state=True)
    y2, st2 = ops.covenant_ssd(x[:, half:], dt[:, half:], A, B[:, half:],
                               C[:, half:], chunk=16, init_state=st1,
                               return_state=True)
    np.testing.assert_allclose(jnp.concatenate([y1, y2], 1), y_full, atol=2e-3)
    np.testing.assert_allclose(st2, st_full, atol=2e-3)


def test_ssd_decay_reduces_state_influence():
    """Sanity: large |A| (fast decay) -> final state smaller in norm."""
    b, s, h, p, g, n = 1, 32, 2, 4, 2, 4
    x = randn(b, s, h, p)
    dt = jnp.full((b, s, h), 0.1, jnp.float32)
    B, C = randn(b, s, g, n), randn(b, s, g, n)
    _, st_slow = ops.covenant_ssd(x, dt, jnp.asarray([-0.1, -0.1]), B, C,
                                  chunk=16, return_state=True)
    _, st_fast = ops.covenant_ssd(x, dt, jnp.asarray([-8.0, -8.0]), B, C,
                                  chunk=16, return_state=True)
    assert float(jnp.linalg.norm(st_fast)) < float(jnp.linalg.norm(st_slow))


# ---------------------------------------------------------------------------
# flash attention backward (Pallas)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("causal,win", [(True, None), (True, 16),
                                        (False, None)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_backward_matches_autodiff(causal, win, dtype):
    from repro.kernels.flash_attention import (flash_attention_bwd,
                                               flash_attention_fwd_lse)
    bh, s, d, bq, bkv = 2, 64, 32, 32, 32
    q = randn(bh, s, d, dtype=dtype)
    k = randn(bh, s, d, dtype=dtype)
    v = randn(bh, s, d, dtype=dtype)
    do = randn(bh, s, d, dtype=dtype)
    out, lse = flash_attention_fwd_lse(q, k, v, causal=causal, window=win,
                                       block_q=bq, block_kv=bkv,
                                       interpret=True)
    dq, dk, dv = flash_attention_bwd(q, k, v, out, lse, do, causal=causal,
                                     window=win, block_q=bq, block_kv=bkv,
                                     interpret=True)

    def loss(q_, k_, v_):
        o = ref.attention_ref(q_.reshape(1, bh, s, d),
                              k_.reshape(1, bh, s, d),
                              v_.reshape(1, bh, s, d),
                              causal=causal, window=win)
        return jnp.sum(o.reshape(bh, s, d).astype(jnp.float32)
                       * do.astype(jnp.float32))

    gd = jax.grad(loss, argnums=(0, 1, 2))(q, k, v)
    tol = 2e-2 if dtype == jnp.bfloat16 else 2e-3
    for a, b in zip((dq, dk, dv), gd):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), atol=tol)


def test_flash_fwd_lse_matches_plain_forward():
    from repro.kernels.flash_attention import (flash_attention,
                                               flash_attention_fwd_lse)
    bh, s, d = 2, 64, 32
    q, k, v = randn(bh, s, d), randn(bh, s, d), randn(bh, s, d)
    o1 = flash_attention(q, k, v, block_q=32, block_kv=32, interpret=True)
    o2, lse = flash_attention_fwd_lse(q, k, v, block_q=32, block_kv=32,
                                      interpret=True)
    np.testing.assert_allclose(o1, o2, atol=1e-5)
    assert lse.shape == (bh, s, 1)
