"""Per-architecture smoke tests: reduced config, one forward/train step on
CPU, asserting output shapes + no NaNs; plus a prefill/decode round trip."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import get_model


def _batch(model, key, bs=2, seq=16):
    cfg = model.cfg
    ks = jax.random.split(key, 4)
    toks = jax.random.randint(ks[0], (bs, seq), 0, cfg.vocab)
    b = {"tokens": toks, "targets": jnp.roll(toks, -1, 1)}
    for name, (shape_fn, dtype) in model.extra_inputs.items():
        b[name] = jax.random.normal(ks[1], shape_fn(bs, seq), jnp.float32
                                    ).astype(dtype)
    return b


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_train_step(arch):
    cfg = configs.get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(model, jax.random.PRNGKey(1))
    loss, grads = jax.value_and_grad(model.loss_fn)(params, batch)
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    leaves = jax.tree.leaves(grads)
    assert leaves
    for g in leaves:
        assert bool(jnp.all(jnp.isfinite(g))), f"{arch}: non-finite grad"
    # one SGD step reduces loss on the same batch (sanity of the gradient)
    params2 = jax.tree.map(lambda p, g: p - 0.5 * g.astype(p.dtype),
                           params, grads)
    loss2 = model.loss_fn(params2, batch)
    assert float(loss2) < float(loss) + 1e-3, f"{arch}: step did not descend"


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_smoke_serve_round_trip(arch):
    cfg = configs.get_config(arch, smoke=True)
    model = get_model(cfg)
    params = model.init_params(jax.random.PRNGKey(0))
    batch = _batch(model, jax.random.PRNGKey(1), bs=2, seq=12)
    cache = model.init_cache(2, 48)
    logits, cache = model.prefill(params, batch, cache)
    assert logits.shape == (2, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: prefill NaN"
    tok = jnp.argmax(logits, -1).astype(jnp.int32)
    for _ in range(3):
        logits, cache = model.decode_step(params, tok, cache)
        assert logits.shape == (2, cfg.vocab)
        assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: decode NaN"
        tok = jnp.argmax(logits, -1).astype(jnp.int32)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_full_config_dimensions(arch):
    """The full (published) config constructs and has the exact dims."""
    cfg = configs.get_config(arch)
    assert cfg.name == arch
    n_groups, per = cfg.layer_groups()
    assert n_groups * per == cfg.n_layers - cfg.first_dense
    # spot-check published numbers
    table = {
        "command-r-plus-104b": (64, 12288, 96, 8, 33792, 256000),
        "gemma3-12b": (48, 3840, 16, 8, 15360, 262144),
        "stablelm-12b": (40, 5120, 32, 8, 13824, 100352),
        "qwen3-0.6b": (28, 1024, 16, 8, 3072, 151936),
        "deepseek-moe-16b": (28, 2048, 16, 16, None, 102400),
        "olmoe-1b-7b": (16, 2048, 16, 16, None, 50304),
        "zamba2-2.7b": (54, 2560, 32, 32, 10240, 32000),
        "paligemma-3b": (18, 2048, 8, 1, 16384, 257216),
        "mamba2-2.7b": (64, 2560, 0, 0, 0, 50280),
        "whisper-base": (6, 512, 8, 8, 2048, 51865),
    }
    L, d, h, kv, ff, v = table[arch]
    assert cfg.n_layers == L and cfg.d_model == d
    assert cfg.n_heads == h and cfg.n_kv_heads == kv
    if ff is not None:
        assert cfg.d_ff == ff
    assert cfg.vocab == v


def test_cell_count():
    all_cells = configs.cells(include_skipped=True)
    assert len(all_cells) == 40
    runnable = [c for c in all_cells if c[2]]
    skipped = [c for c in all_cells if not c[2]]
    assert len(skipped) == 7  # long_500k skipped for 7 full-attention archs
    assert all(s == "long_500k" for _, s, ok, _ in all_cells if not ok)
