"""Examples must stay runnable (they are the public-API contract)."""
import os
import subprocess
import sys

import pytest

ROOT = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _run(script, timeout=600, extra=()):
    env = dict(os.environ)
    env["PYTHONPATH"] = "src"
    return subprocess.run([sys.executable, script, *extra],
                          capture_output=True, text=True, env=env,
                          cwd=ROOT, timeout=timeout)


def test_quickstart_runs():
    r = _run("examples/quickstart.py")
    assert r.returncode == 0, r.stderr[-2000:]
    assert "correct=True" in r.stdout
    assert "=== hvx ===" in r.stdout and "=== dnnweaver ===" in r.stdout


def test_train_lm_learns(tmp_path):
    # 30 jax training steps with simulated stragglers run ~14 min on a
    # loaded CI host; 900s flaked right at the margin
    r = _run("examples/train_lm.py", timeout=1800,
             extra=("--steps", "30", "--ckpt-dir", str(tmp_path)))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "loss" in r.stdout


@pytest.mark.slow
def test_compile_layers_sweep():
    r = _run("examples/compile_layers.py", timeout=1200)
    assert r.returncode == 0, r.stderr[-2000:]
    assert "BERT-LG-GEMM1" in r.stdout


@pytest.mark.slow
def test_sweep_variants_example(tmp_path):
    r = _run("examples/sweep_variants.py", timeout=1200,
             extra=("--workers", "2", "--store", str(tmp_path / "store")))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "each compiled exactly once" in r.stdout
    warm = [l for l in r.stdout.splitlines() if l.startswith("[warm]")]
    assert warm and ", 0 pipeline stages run" in warm[0]


@pytest.mark.search
def test_warm_start_search_example(tmp_path):
    r = _run("examples/warm_start_search.py", timeout=1200,
             extra=("--store", str(tmp_path / "store")))
    assert r.returncode == 0, r.stderr[-2000:]
    assert "winners pinned" in r.stdout
    assert "seed(s) injected" in r.stdout
    assert "warm-start index:" in r.stdout
