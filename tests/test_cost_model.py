"""Simulator-agreement property suite for the analytic cost model and the
``prefix_bound`` admissibility invariant beam pruning depends on.

Two invariants, the contract between ``core/cost.py`` and ``core/stream.py``:

* **exactness** — for any valid schedule point on shapes small enough to
  stream, ``cost.cost(pack=False)`` equals the stream machine's serial
  cycle count *exactly* (the model is mnemonic-faithful, not approximate);
* **admissibility** — ``cost.prefix_bound`` of any partial tiling
  commitment is never greater than the full-schedule cost of ANY
  completion, in both the packed and serial forms.  This is what makes
  beam pruning safe: a pruned prefix provably had no completion better
  than the incumbent bound ordering suggested.

The hypothesis half reuses the ``test_property_pipeline.py`` harness idiom
(random small problems, both eval targets); the seeded half mirrors the
same invariants without the hypothesis dependency, so the suite still
bites in environments without it.
"""
import random

import numpy as np
import pytest

from repro.core import codegen, cost, library, stream, targets
from repro.core.pipeline import CompileOptions, Pipeline
from repro.core.scheduler import schedule_space
from repro.core.search import materialise

pytestmark = pytest.mark.search

try:
    from hypothesis import given, settings, strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:  # container may lack it; the seeded mirrors still run
    HAVE_HYPOTHESIS = False

TARGETS = ("hvx", "dnnweaver")
UNROLLS = (1, 2, 4, 8)


def _point_ctx(cdlt, acg, tiling, unroll):
    """Materialise one schedule point through the stock pipeline."""
    pl = Pipeline.default().with_acg_hooks(acg)
    return materialise(cdlt, acg, pl, CompileOptions(),
                       {"tiling": dict(tiling), "unroll_factor": unroll})


def _space(cdlt, acg, max_candidates=256):
    space = schedule_space(cdlt, acg, max_candidates=max_candidates)
    assert space.tilings
    return space


def _assert_admissible(space, acg, committed, full_cycles, pack):
    bound = cost.prefix_bound(space.probe, acg, space.plans, committed,
                              divisors=space.divisors, pack=pack)
    assert bound <= full_cycles + 1e-6, (
        f"prefix_bound({committed}, pack={pack}) = {bound} exceeds a "
        f"completion's cost {full_cycles}")


def _check_point(cdlt, acg, space, tiling, unroll, rng):
    """Both invariants for one (point, committed-subset) draw."""
    ctx = _point_ctx(cdlt, acg, tiling, unroll)
    sub = {v: tiling[v] for v in sorted(tiling) if rng.random() < 0.5}
    for pack in (False, True):
        full = cost.cost(ctx.cdlt, acg, pack=pack).cycles
        for committed in ({}, sub, dict(tiling)):
            _assert_admissible(space, acg, committed, full, pack)
    return ctx


# ---------------------------------------------------------------------------
# hypothesis half
# ---------------------------------------------------------------------------

if HAVE_HYPOTHESIS:

    @st.composite
    def gemm_point(draw):
        m = draw(st.integers(1, 12))
        n = draw(st.integers(1, 12))
        k = draw(st.integers(1, 12))
        pick = draw(st.integers(0, 10 ** 6))
        unroll = draw(st.sampled_from(UNROLLS))
        sub_seed = draw(st.integers(0, 10 ** 6))
        return m, n, k, pick, unroll, sub_seed

    @given(gemm_point(), st.sampled_from(TARGETS))
    @settings(max_examples=15, deadline=None)
    def test_cost_equals_stream_serial_cycles_exactly(prob, target):
        """Random valid schedule points on small GEMMs: the analytic model
        and the stream simulator agree EXACTLY on serial cycles."""
        m, n, k, pick, unroll, _ = prob
        acg = targets.get_target(target)
        cdlt = library.gemm(m, n, k, in_dtype="u8")
        space = _space(cdlt, acg)
        tiling = space.tilings[pick % len(space.tilings)]
        ctx = _point_ctx(cdlt, acg, tiling, unroll)
        try:
            prog = codegen.generate(ctx.cdlt, acg, max_mnemonics=60_000)
        except codegen.StreamTooLarge:
            return
        rng = np.random.default_rng(m * 131 + n * 17 + k)
        ins = {"A": rng.integers(0, 5, (m, k)).astype(np.uint8),
               "B": rng.integers(0, 5, (k, n)).astype(np.uint8)}
        res = stream.run_stream(prog, ins, pack=False)
        analytic = cost.cost(ctx.cdlt, acg, pack=False).cycles
        assert res.serial_cycles == pytest.approx(analytic, abs=1e-9)

    @given(gemm_point(), st.sampled_from(TARGETS))
    @settings(max_examples=15, deadline=None)
    def test_prefix_bound_is_admissible(prob, target):
        """prefix_bound of any committed sub-tiling never exceeds the full
        cost of any completion (both pack modes, empty/partial/full
        commitment)."""
        m, n, k, pick, unroll, sub_seed = prob
        acg = targets.get_target(target)
        cdlt = library.gemm(m, n, k, in_dtype="u8")
        space = _space(cdlt, acg)
        tiling = space.tilings[pick % len(space.tilings)]
        _check_point(cdlt, acg, space, tiling, unroll,
                     random.Random(sub_seed))


# ---------------------------------------------------------------------------
# seeded mirrors — same invariants, no hypothesis required
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("target", TARGETS)
def test_seeded_cost_stream_agreement_gemm(target, rng):
    py_rng = random.Random(17)
    checked = 0
    while checked < 6:
        m, n, k = (py_rng.randint(1, 10) for _ in range(3))
        acg = targets.get_target(target)
        cdlt = library.gemm(m, n, k, in_dtype="u8")
        space = _space(cdlt, acg)
        tiling = py_rng.choice(space.tilings)
        unroll = py_rng.choice(UNROLLS)
        ctx = _point_ctx(cdlt, acg, tiling, unroll)
        try:
            prog = codegen.generate(ctx.cdlt, acg, max_mnemonics=60_000)
        except codegen.StreamTooLarge:
            continue
        ins = {"A": rng.integers(0, 5, (m, k)).astype(np.uint8),
               "B": rng.integers(0, 5, (k, n)).astype(np.uint8)}
        res = stream.run_stream(prog, ins, pack=False)
        analytic = cost.cost(ctx.cdlt, acg, pack=False).cycles
        assert res.serial_cycles == pytest.approx(analytic, abs=1e-9), \
            (m, n, k, tiling, unroll)
        checked += 1


@pytest.mark.parametrize("target", TARGETS)
def test_seeded_prefix_bound_admissible_gemm(target):
    py_rng = random.Random(23)
    for _ in range(10):
        m, n, k = (py_rng.randint(1, 12) for _ in range(3))
        acg = targets.get_target(target)
        cdlt = library.gemm(m, n, k, in_dtype="u8")
        space = _space(cdlt, acg)
        _check_point(cdlt, acg, space, py_rng.choice(space.tilings),
                     py_rng.choice(UNROLLS), py_rng)


@pytest.mark.parametrize("target", TARGETS)
def test_seeded_prefix_bound_admissible_conv_elementwise(target):
    """Admissibility must survive clamped conv footprints (halo overlap)
    and 1-D elementwise codelets, not just perfect GEMM nests."""
    py_rng = random.Random(5)
    acg = targets.get_target(target)
    builders = [
        lambda: library.conv2d(1, py_rng.randint(6, 12),
                               py_rng.randint(6, 12), py_rng.choice([1, 3]),
                               py_rng.choice([4, 8]), 3, 3,
                               py_rng.choice([1, 2])),
        lambda: library.elementwise("ADD", py_rng.randint(2, 96), "i32"),
    ]
    for _ in range(6):
        cdlt = py_rng.choice(builders)()
        space = _space(cdlt, acg, max_candidates=128)
        _check_point(cdlt, acg, space, py_rng.choice(space.tilings),
                     py_rng.choice(UNROLLS), py_rng)


def test_prefix_bound_tightens_with_commitment():
    """Committing loops can only raise (never lower) the bound: committed
    loops cost exactly, so information monotonically narrows the
    relaxation.  Checked along random commitment chains."""
    py_rng = random.Random(11)
    acg = targets.get_target("hvx")
    cdlt = library.gemm(24, 32, 16, in_dtype="u8")
    space = _space(cdlt, acg)
    for _ in range(10):
        tiling = py_rng.choice(space.tilings)
        committed: dict = {}
        prev = cost.prefix_bound(space.probe, acg, space.plans, committed,
                                 divisors=space.divisors)
        for var in space.loop_order():
            committed[var] = tiling[var]
            cur = cost.prefix_bound(space.probe, acg, space.plans,
                                    committed, divisors=space.divisors)
            assert cur >= prev - 1e-9, (tiling, committed, cur, prev)
            prev = cur


def test_prefix_bound_is_deterministic():
    acg = targets.get_target("dnnweaver")
    cdlt = library.gemm(16, 24, 8, in_dtype="u8")
    space = _space(cdlt, acg)
    committed = {"m": 4, "k": 8}
    a = [cost.prefix_bound(space.probe, acg, space.plans, committed,
                           divisors=space.divisors) for _ in range(3)]
    assert len(set(a)) == 1


def test_transfer_hot_vars_names_dominant_operand_loops():
    """On a reload-heavy tiling the hot vars are loop vars of the operand
    with the dominant staging traffic — and always a subset of the
    tiling's loops (mutation can act on every one of them)."""
    acg = targets.get_target("hvx")
    cdlt = library.gemm(24, 32, 16, in_dtype="u8")
    space = _space(cdlt, acg)
    worst = {v: 1 for v in space.loop_order()}
    hot = cost.transfer_hot_vars(space.probe, acg, space.plans, worst,
                                 divisors=space.divisors)
    assert hot and set(hot) <= set(worst)
    assert hot == sorted(hot)  # deterministic order for seed-stable search
