"""Search-based scheduling (core/search.py): a driver subsystem — never
worse than the heuristic, functionally correct, deterministic per seed,
strategy-pluggable, and materialised exclusively through the pipeline.
PR 5 additions: the cost-bound-guided ``beam`` strategy, transfer-aware
mutation, warm-starting from the artifact store, and the budget-matched
acceptance comparisons."""
import dataclasses

import numpy as np
import pytest

import repro
from repro.core import interp, library, targets
from repro.core.search import (STRATEGIES, SearchOptions, SearchResult,
                               _mutate, search_schedule)
from repro.core.scheduler import schedule_space
from repro.core.store import ArtifactStore


@pytest.mark.parametrize("target", ["hvx", "dnnweaver"])
def test_search_never_worse_and_correct(target, rng):
    acg = targets.get_target(target)
    cdlt = library.gemm(24, 32, 16, in_dtype="u8")
    res = search_schedule(cdlt, acg, generations=4, population=10, seed=1)
    assert res.best_cycles <= res.heuristic_cycles
    # the search's heuristic baseline is exactly the driver's schedule
    assert res.heuristic_cycles == repro.compile(cdlt, target).cycles()
    assert res.evaluated > 5
    ins = {"A": rng.integers(0, 5, (24, 16)).astype(np.uint8),
           "B": rng.integers(0, 5, (16, 32)).astype(np.uint8)}
    got = interp.run(res.best, acg, ins)
    np.testing.assert_array_equal(got["C"], cdlt.oracle(ins)["C"])


def test_search_improves_some_layer():
    """Across a few Table-2 layers the search beats the greedy heuristic on
    at least one (the heuristic's tile pick is cost-model-suboptimal
    somewhere — that gap is exactly what §4 says search should close)."""
    acg = targets.get_target("hvx")
    gains = []
    for spec in library.PAPER_LAYERS[6:10]:  # DLRM FC stack (fast)
        res = search_schedule(spec.build(), acg, generations=5,
                              population=12, seed=0)
        gains.append(res.gain)
    assert max(gains) > 1.0
    assert all(g >= 1.0 - 1e-9 for g in gains)


def test_search_deterministic_trace():
    """Same seed + same inputs -> identical trace, winner and evaluation
    count (candidate generation and mutation draw from separate seeded
    streams, so strategy interleaving cannot skew replay)."""
    acg = targets.get_target("hvx")

    def run():
        return search_schedule(library.gemm(24, 32, 16, in_dtype="u8"), acg,
                               generations=4, population=10, seed=7)

    a, b = run(), run()
    assert a.trace == b.trace
    assert a.point == b.point
    assert a.evaluated == b.evaluated
    assert a.best_cycles == b.best_cycles


def test_strategy_registry_complete_and_never_worse():
    assert {"beam", "evolutionary", "random", "grid",
            "exhaustive"} <= set(STRATEGIES)
    acg = targets.get_target("hvx")
    results = {}
    for strategy in ("beam", "evolutionary", "random", "grid", "exhaustive"):
        res = search_schedule(library.gemm(8, 16, 12, in_dtype="u8"), acg,
                              strategy=strategy, generations=2,
                              population=6, seed=0)
        assert res.best_cycles <= res.heuristic_cycles
        assert res.strategy == strategy
        results[strategy] = res
    # exhaustive visits the whole space: nothing beats its optimum
    assert all(results["exhaustive"].best_cycles <= r.best_cycles + 1e-9
               for r in results.values())
    with pytest.raises(KeyError):
        search_schedule(library.gemm(4, 8, 4, in_dtype="u8"), acg,
                        strategy="simulated-annealing")


def test_mutation_moves_one_tile_to_neighbouring_divisor():
    """The evolutionary mutation steps ONE loop's tile factor to an
    adjacent divisor on its grid (or flips unroll) — not a +-k hop in a
    flat enumeration index — and never leaves the valid region."""
    import random
    acg = targets.get_target("hvx")
    space = schedule_space(library.gemm(24, 32, 16, in_dtype="u8"), acg)
    base = tuple(sorted(space.tilings[0].items()))
    rng = random.Random(3)
    unrolls = (1, 2, 4, 8)
    for _ in range(50):
        new_t, new_u = _mutate((base, 4), space, unrolls, rng)
        changed = [(v, f) for (v, f), (v0, f0) in zip(new_t, base) if f != f0]
        if new_u != 4:
            assert not changed              # unroll flip leaves tiling alone
            assert new_u in unrolls
        elif changed:
            assert len(changed) == 1        # exactly one loop moved
            var, factor = changed[0]
            grid = space.divisors[var]
            old = dict(base)[var]
            assert abs(grid.index(factor) - grid.index(old)) == 1
            assert space.valid(dict(new_t))


def test_search_space_is_pipeline_fed():
    """schedule_space runs the whole pre-tiling pipeline prefix (honouring
    target hooks, including ones spliced after map_compute), so search
    enumerates against exactly what candidate materialisation sees."""
    acg = targets.get_target("hvx")
    seen = []
    acg.extra_passes.append(
        ("after:place", "probe-spy", lambda ctx: seen.append("early")))
    acg.extra_passes.append(
        ("after:map_compute", "late-spy", lambda ctx: seen.append("late")))
    try:
        space = schedule_space(library.gemm(8, 16, 12, in_dtype="u8"), acg)
    finally:
        acg.extra_passes.clear()
    assert seen == ["early", "late"]
    assert space.tilings and all(space.valid(t) for t in space.tilings[:20])


# ---------------------------------------------------------------------------
# PR 5: determinism regression — every registered strategy, byte-identical
# ---------------------------------------------------------------------------


@pytest.mark.search
@pytest.mark.parametrize("strategy", sorted(STRATEGIES))
def test_every_strategy_trace_byte_identical_per_seed(strategy):
    """Same seed => byte-identical ``SearchResult.trace`` (repr compare),
    same winner, same evaluation count — for EVERY registered strategy,
    including the rng-free ``beam``.  This is the invariant that makes
    store entries reproducible across processes and sweep backends."""
    acg = targets.get_target("dnnweaver")

    def run():
        return search_schedule(library.gemm(24, 32, 16, in_dtype="u8"), acg,
                               strategy=strategy, generations=3,
                               population=8, seed=11, max_candidates=256)

    a, b = run(), run()
    assert repr(a.trace).encode() == repr(b.trace).encode()
    assert a.point == b.point
    assert a.evaluated == b.evaluated
    assert a.best_cycles == b.best_cycles


# ---------------------------------------------------------------------------
# PR 5: SearchResult.gain degenerate edge
# ---------------------------------------------------------------------------


def test_gain_returns_zero_at_the_zero_cycle_optimum_edge():
    """best == baseline == 0 (the seed point already hits the space
    optimum of a degenerate zero-cost schedule) must report 0.0, not
    divide by zero (or the old near-zero-division blow-up)."""
    def res(best, heur):
        return SearchResult(best=None, best_cycles=best,
                            heuristic_cycles=heur, evaluated=1, trace=[])

    assert res(0.0, 0.0).gain == 0.0
    assert res(0.0, 10.0).gain == float("inf")  # genuinely unbounded
    assert res(50.0, 100.0).gain == pytest.approx(2.0)


# ---------------------------------------------------------------------------
# PR 5: transfer-aware mutation
# ---------------------------------------------------------------------------


def test_mutation_prefer_biases_but_stays_neighbouring():
    """With a ``prefer`` pool, every tiling mutation moves one of the
    preferred loops (still one divisor step, still valid); unroll flips
    are unaffected."""
    import random
    acg = targets.get_target("hvx")
    space = schedule_space(library.gemm(24, 32, 16, in_dtype="u8"), acg)
    base = tuple(sorted(space.tilings[0].items()))
    rng = random.Random(7)
    moved = set()
    for _ in range(60):
        new_t, new_u = _mutate((base, 4), space, (1, 2, 4, 8), rng,
                               prefer=("k",))
        changed = [(v, f) for (v, f), (v0, f0) in zip(new_t, base)
                   if f != f0]
        if new_u == 4 and changed:
            assert len(changed) == 1
            moved.add(changed[0][0])
    assert moved == {"k"}


def test_hot_vars_only_for_transfer_dominated_reports():
    """_hot_vars consults the evaluated parent's CostReport: a compute-
    dominated parent gets no bias, a transfer-dominated one gets the
    dominant operand's loops."""
    from repro.core.cost import CostReport
    from repro.core.search import _hot_vars
    acg = targets.get_target("hvx")
    space = schedule_space(library.gemm(24, 32, 16, in_dtype="u8"), acg)
    pt = (tuple(sorted(space.tilings[0].items())), 4)

    def fake_eval(reports):
        def evaluate(p):
            return 0.0
        evaluate.reports = reports
        return evaluate

    mem_heavy = CostReport(cycles=10, compute_cycles=1, transfer_cycles=9,
                           overhead_cycles=0, compute_invocations=1,
                           transfer_mnemonics=9)
    cpu_heavy = CostReport(cycles=10, compute_cycles=9, transfer_cycles=1,
                           overhead_cycles=0, compute_invocations=9,
                           transfer_mnemonics=1)
    assert _hot_vars(space, pt, fake_eval({pt: mem_heavy}), {})
    assert _hot_vars(space, pt, fake_eval({pt: cpu_heavy}), {}) == []


# ---------------------------------------------------------------------------
# PR 5: warm-starting from the artifact store
# ---------------------------------------------------------------------------


@pytest.mark.search
def test_warm_start_seeds_from_store_and_never_hurts(tmp_path):
    """A store populated by a previous search seeds a later search of the
    same-shaped layer: seeds are injected (``seeded > 0``), the result is
    at least as good as cold, and a cold store yields zero seeds."""
    repro.clear_cache()
    store = ArtifactStore(str(tmp_path / "store"))
    pre = SearchOptions(strategy="beam", generations=3, population=8,
                        seed=0, max_candidates=256)
    repro.compile("DLRM-FC2", "hvx",
                  repro.CompileOptions(search=pre, store=store))

    warm = SearchOptions(strategy="evolutionary", generations=3,
                         population=8, seed=9, max_candidates=256,
                         warm_start=True)
    cold = dataclasses.replace(warm, warm_start=False)
    a_w = repro.compile("DLRM-FC2", "hvx",
                        repro.CompileOptions(search=warm, store=store))
    a_c = repro.compile("DLRM-FC2", "hvx",
                        repro.CompileOptions(search=cold, store=store))
    assert a_w.search.seeded > 0
    assert a_c.search.seeded == 0
    assert a_w.search.best_cycles <= a_c.search.best_cycles + 1e-9
    assert a_w.key != a_c.key       # warm_start is part of the identity

    # an empty store warm-starts to nothing (and must not fail)
    repro.clear_cache()
    empty = ArtifactStore(str(tmp_path / "empty"))
    a_e = repro.compile("DLRM-FC2", "hvx",
                        repro.CompileOptions(search=warm, store=empty))
    assert a_e.search.seeded == 0


@pytest.mark.search
def test_warm_start_entry_roundtrips_seeded_and_sig(tmp_path):
    """The store entry persists ``seeded``/``space_sig``; a fresh-process
    restore reports them without re-searching."""
    repro.clear_cache()
    store = ArtifactStore(str(tmp_path / "store"))
    sopts = SearchOptions(strategy="beam", generations=2, population=6,
                          seed=0, max_candidates=128)
    art = repro.compile("DLRM-FC3", "hvx",
                        repro.CompileOptions(search=sopts, store=store))
    sig = art.search.space_sig
    assert sig
    repro.clear_cache()
    warm = repro.compile("DLRM-FC3", "hvx",
                         repro.CompileOptions(search=sopts, store=store))
    assert warm.ctx.executed == []          # zero-stage restore
    assert warm.search.space_sig == sig
    assert warm.search.seeded == art.search.seeded


# ---------------------------------------------------------------------------
# PR 5: budget-matched acceptance — beam vs evolutionary
# ---------------------------------------------------------------------------

FAST_LAYERS = ["DLRM-FC1", "DLRM-FC2", "DLRM-FC3"]


@pytest.mark.search
@pytest.mark.parametrize("target", ["hvx", "dnnweaver"])
def test_beam_budget_matched_on_dlrm_subset(target):
    """The CI-sized acceptance: on the DLRM subset, beam at an equal
    evaluation budget finds cycles <= evolutionary's."""
    acg = targets.get_target(target)
    for key in FAST_LAYERS:
        cdlt = library.paper_layer(key)
        rb = search_schedule(cdlt, acg, strategy="beam", generations=2,
                             population=8, seed=0, max_candidates=512)
        re_ = search_schedule(cdlt, acg, strategy="evolutionary",
                              generations=2, population=8, seed=0,
                              max_candidates=512)
        assert rb.evaluated <= 16           # the shared budget
        assert rb.best_cycles <= re_.best_cycles + 1e-9, (key, target)


@pytest.mark.slow
@pytest.mark.search
@pytest.mark.parametrize("target", ["hvx", "dnnweaver"])
def test_beam_matches_or_beats_evolutionary_every_paper_layer(target):
    """Acceptance: on every Table-2 layer x both eval targets, beam under
    an equal ``evaluate()`` budget matches or beats evolutionary."""
    acg = targets.get_target(target)
    budget = 16
    for spec in library.PAPER_LAYERS:
        rb = search_schedule(spec.build(), acg, strategy="beam",
                             generations=2, population=8, seed=0,
                             max_candidates=512)
        re_ = search_schedule(spec.build(), acg, strategy="evolutionary",
                              generations=2, population=8, seed=0,
                              max_candidates=512)
        assert rb.evaluated <= budget
        assert rb.best_cycles <= re_.best_cycles + 1e-9, (
            spec.key, target, rb.best_cycles, re_.best_cycles)


@pytest.mark.slow
@pytest.mark.search
def test_warm_started_evolutionary_converges_in_fewer_evaluations(tmp_path):
    """Acceptance: with the store carrying a previous search's best point,
    warm-started evolutionary converges earlier than cold — strictly
    shorter trace (patience cuts it at the plateau) and strictly fewer
    evaluations, at an equal-or-better final schedule."""
    repro.clear_cache()
    store = ArtifactStore(str(tmp_path / "store"))
    pre = SearchOptions(strategy="beam", generations=4, population=10,
                        seed=0, max_candidates=256)
    repro.compile("InceptionV3-FC1", "hvx",
                  repro.CompileOptions(search=pre, store=store))
    base = SearchOptions(strategy="evolutionary", generations=10,
                         population=10, seed=3, max_candidates=256,
                         patience=2)
    warm = dataclasses.replace(base, warm_start=True)
    a_w = repro.compile("InceptionV3-FC1", "hvx",
                        repro.CompileOptions(search=warm, store=store))
    a_c = repro.compile("InceptionV3-FC1", "hvx",
                        repro.CompileOptions(search=base, store=store))
    assert len(a_w.search.trace) < len(a_c.search.trace)
    assert a_w.search.evaluated < a_c.search.evaluated
    assert a_w.search.best_cycles <= a_c.search.best_cycles + 1e-9


def test_driver_search_option_every_paper_layer_both_targets():
    """Acceptance: CompileOptions(search=...) returns an artifact at least
    as good as the heuristic for every paper layer on both targets, with
    the search trace attached, under the same content-addressed scheme."""
    sopts = repro.SearchOptions(strategy="random", generations=1,
                                population=4, seed=0, max_candidates=128)
    for target in ("hvx", "dnnweaver"):
        for spec in library.PAPER_LAYERS:
            heur = repro.compile(spec, target)
            art = repro.compile(spec, target,
                                repro.CompileOptions(search=sopts))
            assert art.cycles() <= heur.cycles() + 1e-9, (spec.key, target)
            assert art.search is not None and art.search.trace
            assert art.key != heur.key      # searched compile is its own key
