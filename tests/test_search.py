"""Search-based scheduling (core/search.py): never worse than the
heuristic, produces functionally correct schedules, and improves at least
one paper layer."""
import numpy as np
import pytest

import repro
from repro.core import interp, library, targets
from repro.core.search import search_schedule


@pytest.mark.parametrize("target", ["hvx", "dnnweaver"])
def test_search_never_worse_and_correct(target, rng):
    acg = targets.get_target(target)
    cdlt = library.gemm(24, 32, 16, in_dtype="u8")
    res = search_schedule(cdlt, acg, generations=4, population=10, seed=1)
    assert res.best_cycles <= res.heuristic_cycles
    # the search's heuristic baseline is exactly the driver's schedule
    assert res.heuristic_cycles == repro.compile(cdlt, target).cycles()
    assert res.evaluated > 5
    ins = {"A": rng.integers(0, 5, (24, 16)).astype(np.uint8),
           "B": rng.integers(0, 5, (16, 32)).astype(np.uint8)}
    got = interp.run(res.best, acg, ins)
    np.testing.assert_array_equal(got["C"], cdlt.oracle(ins)["C"])


def test_search_improves_some_layer():
    """Across a few Table-2 layers the search beats the greedy heuristic on
    at least one (the heuristic's tile pick is cost-model-suboptimal
    somewhere — that gap is exactly what §4 says search should close)."""
    acg = targets.get_target("hvx")
    gains = []
    for spec in library.PAPER_LAYERS[6:10]:  # DLRM FC stack (fast)
        res = search_schedule(spec.build(), acg, generations=5,
                              population=12, seed=0)
        gains.append(res.gain)
    assert max(gains) > 1.0
    assert all(g >= 1.0 - 1e-9 for g in gains)
