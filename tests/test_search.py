"""Search-based scheduling (core/search.py): a driver subsystem — never
worse than the heuristic, functionally correct, deterministic per seed,
strategy-pluggable, and materialised exclusively through the pipeline."""
import numpy as np
import pytest

import repro
from repro.core import interp, library, targets
from repro.core.search import (STRATEGIES, SearchOptions, _mutate,
                               search_schedule)
from repro.core.scheduler import schedule_space


@pytest.mark.parametrize("target", ["hvx", "dnnweaver"])
def test_search_never_worse_and_correct(target, rng):
    acg = targets.get_target(target)
    cdlt = library.gemm(24, 32, 16, in_dtype="u8")
    res = search_schedule(cdlt, acg, generations=4, population=10, seed=1)
    assert res.best_cycles <= res.heuristic_cycles
    # the search's heuristic baseline is exactly the driver's schedule
    assert res.heuristic_cycles == repro.compile(cdlt, target).cycles()
    assert res.evaluated > 5
    ins = {"A": rng.integers(0, 5, (24, 16)).astype(np.uint8),
           "B": rng.integers(0, 5, (16, 32)).astype(np.uint8)}
    got = interp.run(res.best, acg, ins)
    np.testing.assert_array_equal(got["C"], cdlt.oracle(ins)["C"])


def test_search_improves_some_layer():
    """Across a few Table-2 layers the search beats the greedy heuristic on
    at least one (the heuristic's tile pick is cost-model-suboptimal
    somewhere — that gap is exactly what §4 says search should close)."""
    acg = targets.get_target("hvx")
    gains = []
    for spec in library.PAPER_LAYERS[6:10]:  # DLRM FC stack (fast)
        res = search_schedule(spec.build(), acg, generations=5,
                              population=12, seed=0)
        gains.append(res.gain)
    assert max(gains) > 1.0
    assert all(g >= 1.0 - 1e-9 for g in gains)


def test_search_deterministic_trace():
    """Same seed + same inputs -> identical trace, winner and evaluation
    count (candidate generation and mutation draw from separate seeded
    streams, so strategy interleaving cannot skew replay)."""
    acg = targets.get_target("hvx")

    def run():
        return search_schedule(library.gemm(24, 32, 16, in_dtype="u8"), acg,
                               generations=4, population=10, seed=7)

    a, b = run(), run()
    assert a.trace == b.trace
    assert a.point == b.point
    assert a.evaluated == b.evaluated
    assert a.best_cycles == b.best_cycles


def test_strategy_registry_complete_and_never_worse():
    assert {"evolutionary", "random", "grid",
            "exhaustive"} <= set(STRATEGIES)
    acg = targets.get_target("hvx")
    results = {}
    for strategy in ("evolutionary", "random", "grid", "exhaustive"):
        res = search_schedule(library.gemm(8, 16, 12, in_dtype="u8"), acg,
                              strategy=strategy, generations=2,
                              population=6, seed=0)
        assert res.best_cycles <= res.heuristic_cycles
        assert res.strategy == strategy
        results[strategy] = res
    # exhaustive visits the whole space: nothing beats its optimum
    assert all(results["exhaustive"].best_cycles <= r.best_cycles + 1e-9
               for r in results.values())
    with pytest.raises(KeyError):
        search_schedule(library.gemm(4, 8, 4, in_dtype="u8"), acg,
                        strategy="simulated-annealing")


def test_mutation_moves_one_tile_to_neighbouring_divisor():
    """The evolutionary mutation steps ONE loop's tile factor to an
    adjacent divisor on its grid (or flips unroll) — not a +-k hop in a
    flat enumeration index — and never leaves the valid region."""
    import random
    acg = targets.get_target("hvx")
    space = schedule_space(library.gemm(24, 32, 16, in_dtype="u8"), acg)
    base = tuple(sorted(space.tilings[0].items()))
    rng = random.Random(3)
    unrolls = (1, 2, 4, 8)
    for _ in range(50):
        new_t, new_u = _mutate((base, 4), space, unrolls, rng)
        changed = [(v, f) for (v, f), (v0, f0) in zip(new_t, base) if f != f0]
        if new_u != 4:
            assert not changed              # unroll flip leaves tiling alone
            assert new_u in unrolls
        elif changed:
            assert len(changed) == 1        # exactly one loop moved
            var, factor = changed[0]
            grid = space.divisors[var]
            old = dict(base)[var]
            assert abs(grid.index(factor) - grid.index(old)) == 1
            assert space.valid(dict(new_t))


def test_search_space_is_pipeline_fed():
    """schedule_space runs the whole pre-tiling pipeline prefix (honouring
    target hooks, including ones spliced after map_compute), so search
    enumerates against exactly what candidate materialisation sees."""
    acg = targets.get_target("hvx")
    seen = []
    acg.extra_passes.append(
        ("after:place", "probe-spy", lambda ctx: seen.append("early")))
    acg.extra_passes.append(
        ("after:map_compute", "late-spy", lambda ctx: seen.append("late")))
    try:
        space = schedule_space(library.gemm(8, 16, 12, in_dtype="u8"), acg)
    finally:
        acg.extra_passes.clear()
    assert seen == ["early", "late"]
    assert space.tilings and all(space.valid(t) for t in space.tilings[:20])


def test_driver_search_option_every_paper_layer_both_targets():
    """Acceptance: CompileOptions(search=...) returns an artifact at least
    as good as the heuristic for every paper layer on both targets, with
    the search trace attached, under the same content-addressed scheme."""
    sopts = repro.SearchOptions(strategy="random", generations=1,
                                population=4, seed=0, max_candidates=128)
    for target in ("hvx", "dnnweaver"):
        for spec in library.PAPER_LAYERS:
            heur = repro.compile(spec, target)
            art = repro.compile(spec, target,
                                repro.CompileOptions(search=sopts))
            assert art.cycles() <= heur.cycles() + 1e-9, (spec.key, target)
            assert art.search is not None and art.search.trace
            assert art.key != heur.key      # searched compile is its own key
