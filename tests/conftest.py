"""Shared fixtures.  NOTE: no XLA_FLAGS here — smoke tests and benches must
see the single real CPU device; only launch/dryrun.py forces 512 host
devices (and only when executed as a script)."""
import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def random_inputs(cdlt, rng, lo=-4, hi=5):
    """Random integer inputs matching a codelet's inp surrogates."""
    ins = {}
    for s in cdlt.surrogates.values():
        if s.kind == "inp":
            low = 0 if s.dtype.name.startswith("u") else lo
            ins[s.name] = rng.integers(low, hi, s.shape).astype(s.dtype.np)
    return ins
