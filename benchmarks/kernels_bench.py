"""Kernel micro-benchmarks: Pallas (interpret) vs jnp reference wall time +
Covenant-tiler BlockSpec report + compile-driver cache behaviour.  On CPU
the absolute times are meaningless for TPU perf; the interesting outputs are
the tiler-chosen block geometries, the (always asserted) numerical
agreement, and the cold-vs-cached ``repro.compile`` latencies."""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro.core import library as covenant_library
from repro.kernels import ops, ref
from repro.kernels.tiling import attention_blocks, gemm_blocks


def _driver_section(emit) -> None:
    """Covenant compile driver: per-target analytic cycles for a mid-size
    GEMM plus the content-addressed cache hit latency."""
    # the cold-timing clear must not wipe the sweep-wide store counters
    # that `benchmarks.run --expect-store-hits` audits at the end
    from repro.core import driver as _driver
    saved = {k: _driver._STATS[k] for k in ("store_hits", "store_misses")}
    repro.clear_cache()
    _driver._STATS.update(saved)
    for target in ("hvx", "dnnweaver"):
        t0 = time.perf_counter()
        art = repro.compile(covenant_library.gemm(64, 64, 64, in_dtype="u8"),
                            target)
        cold = (time.perf_counter() - t0) * 1e6
        t0 = time.perf_counter()
        again = repro.compile(covenant_library.gemm(64, 64, 64, in_dtype="u8"),
                              target)
        warm = (time.perf_counter() - t0) * 1e6
        assert again is art  # served from the cache, no pass re-ran
        emit(f"kernels/driver_compile_{target},{cold:.0f},"
             f"cycles={art.cycles():.0f} cached_us={warm:.0f}")


def _time(fn, *a, reps=3):
    fn(*a)  # compile
    t0 = time.perf_counter()
    for _ in range(reps):
        r = fn(*a)
    jax.block_until_ready(r)
    return (time.perf_counter() - t0) / reps * 1e6


def run(emit):
    rng = np.random.default_rng(0)
    _driver_section(emit)
    # tiler block selections for the paper-relevant GEMMs (Table-2 dims)
    for (m, n, k) in [(384, 4096, 1024), (384, 1024, 4096), (512, 512, 512),
                      (8192, 8192, 8192)]:
        bm, bn, bk = gemm_blocks(m, n, k)
        emit(f"kernels/gemm_blocks_{m}x{n}x{k},0,bm={bm} bn={bn} bk={bk}")
    bq, bkv = attention_blocks(4096, 4096, 128)
    emit(f"kernels/attn_blocks_4k,0,bq={bq} bkv={bkv}")

    a = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((256, 256)), jnp.float32)
    t_k = _time(lambda x, y: ops.covenant_matmul(x, y, blocks=(128, 128, 128)),
                a, b)
    t_r = _time(lambda x, y: ref.matmul_ref(x, y), a, b)
    got = ops.covenant_matmul(a, b, blocks=(128, 128, 128))
    np.testing.assert_allclose(got, ref.matmul_ref(a, b), atol=1e-3)
    emit(f"kernels/matmul_256_interp,{t_k:.0f},ref_us={t_r:.0f} allclose=1")

    q = jnp.asarray(rng.standard_normal((1, 4, 128, 64)), jnp.float32)
    kk = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    vv = jnp.asarray(rng.standard_normal((1, 2, 128, 64)), jnp.float32)
    t_k = _time(lambda x, y, z: ops.covenant_attention(
        x, y, z, blocks=(64, 64)), q, kk, vv)
    got = ops.covenant_attention(q, kk, vv, blocks=(64, 64))
    np.testing.assert_allclose(got, ref.attention_ref(q, kk, vv), atol=2e-3)
    emit(f"kernels/flash_attn_interp,{t_k:.0f},allclose=1")

    x = jnp.asarray(rng.standard_normal((1, 64, 4, 16)), jnp.float32)
    dt = jnp.asarray(rng.uniform(0.01, 0.2, (1, 64, 4)), jnp.float32)
    A = -jnp.asarray(rng.uniform(0.5, 2.0, (4,)), jnp.float32)
    B = jnp.asarray(rng.standard_normal((1, 64, 2, 8)), jnp.float32)
    C = jnp.asarray(rng.standard_normal((1, 64, 2, 8)), jnp.float32)
    t_k = _time(lambda *args: ops.covenant_ssd(*args, chunk=16), x, dt, A, B, C)
    got = ops.covenant_ssd(x, dt, A, B, C, chunk=16)
    np.testing.assert_allclose(got, ref.ssd_ref(x, dt, A, B, C), atol=2e-3)
    emit(f"kernels/ssd_scan_interp,{t_k:.0f},allclose=1")


__all__ = ["run"]
