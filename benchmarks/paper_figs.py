"""Paper-figure benchmarks (Fig 11 / 12 / 13 protocols).

The paper measures cycle counts on vendor cycle-accurate simulators; our
counts come from the mnemonic-faithful analytic model (``core/cost.py``),
which is validated against the executable stream machine on unrollable
layers (tests/test_codegen.py).  nnlib/TVM absolute ratios need Qualcomm's
proprietary stack (DESIGN.md D2); the figures reproduce the paper's own
*relative* protocols:

* Fig 11 — optimized Covenant schedule vs the unoptimized scalar schedule
  per Table-2 layer (the "speedup over baseline" ordering).
* Fig 12 — optimization stacking: +Vectorization, +Mnemonic Packing,
  +Loop Unrolling (the paper's 43x / 2.4x / 1.3x decomposition).
* Fig 13 — multi-target: the same layers compiled for HVX vs DNNWeaver
  (expected: systolic DNNWeaver pulls ahead on large GEMMs).
"""
from __future__ import annotations

import math
import statistics
import time

import repro
from repro.core import library

CONFIGS = {
    "vanilla": repro.CompileOptions(vectorize=False, unroll=False, pack=False),
    "+vec": repro.CompileOptions(vectorize=True, unroll=False, pack=False),
    "+vec+pack": repro.CompileOptions(vectorize=True, unroll=False, pack=True),
    "+vec+pack+unroll": repro.CompileOptions(vectorize=True, unroll=True,
                                             pack=True),
}


def layer_cycles(spec, target, cfg: repro.CompileOptions) -> float:
    """Analytic cycles via the compile driver; repeated (layer, target,
    config) points across fig11/fig12/fig13 are served from the cache."""
    return repro.compile(spec, target, cfg).cycles()


def fig11(emit) -> dict:
    """Covenant (optimized) vs unoptimized scalar baseline on HVX."""
    speedups = {}
    for spec in library.PAPER_LAYERS:
        t0 = time.perf_counter()
        base = layer_cycles(spec, "hvx", CONFIGS["vanilla"])
        opt = layer_cycles(spec, "hvx", CONFIGS["+vec+pack+unroll"])
        us = (time.perf_counter() - t0) * 1e6
        speedups[spec.key] = base / opt
        emit(f"fig11/{spec.key},{us:.0f},speedup={base / opt:.1f}")
    gmean = math.exp(statistics.mean(math.log(s) for s in speedups.values()))
    emit(f"fig11/geomean,0,speedup={gmean:.1f}")
    return speedups


def fig12(emit) -> dict:
    """Optimization stacking on HVX (the Fig-12 ablation)."""
    stages = list(CONFIGS)
    table: dict[str, dict] = {}
    for spec in library.PAPER_LAYERS:
        cycles = {}
        for stage in stages:
            cycles[stage] = layer_cycles(spec, "hvx", CONFIGS[stage])
        table[spec.key] = cycles
    # marginal factors, geometric mean across layers
    factors = {}
    for a, b in zip(stages, stages[1:]):
        fs = [table[k][a] / table[k][b] for k in table if table[k][b] > 0]
        factors[b] = math.exp(statistics.mean(math.log(max(f, 1e-9))
                                              for f in fs))
        emit(f"fig12/{b}_marginal,0,x{factors[b]:.2f}")
    total = [table[k][stages[0]] / table[k][stages[-1]] for k in table]
    gmean = math.exp(statistics.mean(math.log(t) for t in total))
    emit(f"fig12/total_stack,0,x{gmean:.1f}")
    return table


SEARCH = repro.SearchOptions(strategy="evolutionary", generations=4,
                             population=10, seed=0, max_candidates=512)


def fig12_search(emit) -> dict:
    """Beyond-paper: §4's enabled search loop vs the one-shot heuristic —
    now a driver option.  Each paper layer gets a "+search" (evolutionary)
    and a "+beam" row under the SAME evaluation budget, so the rows double
    as the cost-model-guided-vs-stochastic comparison; searched schedules
    flow through the artifact cache/store like any other compile (a warm
    REPRO_CACHE_DIR replays them without re-searching)."""
    import dataclasses

    cfg = CONFIGS["+vec+pack+unroll"]
    cfg_search = dataclasses.replace(cfg, search=SEARCH)
    cfg_beam = dataclasses.replace(
        cfg, search=dataclasses.replace(SEARCH, strategy="beam"))
    gains = {}
    beam_not_worse = 0
    for spec in library.PAPER_LAYERS:
        heur = repro.compile(spec, "hvx", cfg)
        art = repro.compile(spec, "hvx", cfg_search)
        bart = repro.compile(spec, "hvx", cfg_beam)
        gain = heur.cycles() / max(art.cycles(), 1e-9)
        bgain = heur.cycles() / max(bart.cycles(), 1e-9)
        gains[spec.key] = gain
        evaluated = art.search.evaluated if art.search is not None else 0
        bevaluated = bart.search.evaluated if bart.search is not None else 0
        beam_not_worse += bart.cycles() <= art.cycles() + 1e-9
        emit(f"fig12s/{spec.key}+search,0,search_gain=x{gain:.2f} "
             f"evaluated={evaluated}")
        emit(f"fig12s/{spec.key}+beam,0,beam_gain=x{bgain:.2f} "
             f"evaluated={bevaluated}")
    gmean = math.exp(statistics.mean(math.log(max(g, 1e-9))
                                     for g in gains.values()))
    stats = repro.cache_stats()
    emit(f"fig12s/geomean,0,x{gmean:.2f}")
    emit(f"fig12s/beam_not_worse,0,{beam_not_worse}/"
         f"{len(library.PAPER_LAYERS)} layers at equal budget")
    emit(f"fig12s/cache,0,hits={stats['hits']} misses={stats['misses']} "
         f"store_hits={stats['store_hits']} "
         f"store_misses={stats['store_misses']}")
    return gains


def fig15_race(emit, workers: int = 1) -> dict:
    """Beyond-paper: the ``searches=`` racing axis — beam vs evolutionary
    per layer under one budget through the sweep coordinator, winners
    pinned in the store (the ISA-Mapper measurement-database pattern:
    every later compile and warm-started search reuses them)."""
    import dataclasses
    import os
    import tempfile

    from repro.core import store as store_mod

    store = os.environ.get(store_mod.ENV_DIR) \
        or tempfile.mkdtemp(prefix="covenant-race-")
    searches = [SEARCH, dataclasses.replace(SEARCH, strategy="beam")]
    report = repro.sweep([s.key for s in library.PAPER_LAYERS[6:10]],
                         ("hvx", "dnnweaver"), options=CONFIGS["+vec+pack+unroll"],
                         searches=searches, workers=workers,
                         store=store, race=True)
    wins: dict[str, int] = {}
    for pin in report.pins:
        wins[pin["strategy"]] = wins.get(pin["strategy"], 0) + 1
        emit(f"fig15/{pin['layer']}@{pin['target']},0,"
             f"winner={pin['strategy']} cycles={pin['cycles']:.0f}")
    for strat in sorted(wins):
        emit(f"fig15/wins_{strat},0,{wins[strat]}/{len(report.pins)}")
    return wins


# Architecture family for the adaptability sweep (§2's headline claim as a
# benchmark): the registry resolves derived-variant names straight from the
# bundled covenant specs — no compiler edits, no new modules.
VARIANTS = ("dnnweaver", "dnnweaver@pe=32x32", "dnnweaver@pe=16x16")


def fig14_variants(emit, workers: int = 1) -> dict:
    """Beyond-paper: recompile paper layers across a PE-array family
    derived with ``spec.derive`` (string-addressed, content-keyed).  The
    per-variant cycle ratios quantify how much performance the 64x64 array
    buys over scaled-down family members — the design-space-sweep workload
    of arXiv 2111.15024 on top of the covenant registry.

    The sweep runs through the ``repro.sweep`` coordinator — the same
    layers x variants plan CI shards across worker processes — and the
    report's best-variant-per-layer table is emitted as ``fig14/best``
    rows."""
    cfg = CONFIGS["+vec+pack+unroll"]
    report = repro.sweep([s.key for s in library.PAPER_LAYERS], VARIANTS,
                         options=cfg, workers=workers)
    cycles = {(r.layer, r.target): r.cycles for r in report.ok}
    assert len(cycles) == len(library.PAPER_LAYERS) * len(VARIANTS), \
        report.summary()  # every unit keyed separately and succeeded
    table: dict[str, dict] = {}
    for spec in library.PAPER_LAYERS:
        table[spec.key] = {v: cycles[(spec.key, v)] for v in VARIANTS}
        ratios = " ".join(
            f"{v.partition('@')[2] or 'base'}=x"
            f"{table[spec.key][v] / table[spec.key][VARIANTS[0]]:.2f}"
            for v in VARIANTS[1:])
        emit(f"fig14/{spec.key},0,{ratios}")
    for v in VARIANTS[1:]:
        rs = [table[k][v] / table[k][VARIANTS[0]] for k in table]
        gmean = math.exp(statistics.mean(math.log(max(r, 1e-9)) for r in rs))
        emit(f"fig14/geomean_{v.partition('@')[2]},0,x{gmean:.2f}")
    for layer, best in sorted(report.best_by_layer().items()):
        emit(f"fig14/best/{layer},0,variant={best.target} "
             f"cycles={best.cycles:.0f}")
    return table


def fig13(emit) -> dict:
    """HVX vs DNNWeaver, both fully optimized (Fig-13 protocol)."""
    cfg = CONFIGS["+vec+pack+unroll"]
    ratios = {}
    for spec in library.PAPER_LAYERS:
        ch = layer_cycles(spec, "hvx", cfg)
        cd = layer_cycles(spec, "dnnweaver", cfg)
        ratios[spec.key] = ch / cd
        emit(f"fig13/{spec.key},0,hvx/dnnweaver={ch / cd:.1f}")
    gmean = math.exp(statistics.mean(
        math.log(max(r, 1e-9)) for r in ratios.values()))
    emit(f"fig13/geomean,0,ratio={gmean:.1f}")
    # the paper's headline: 490.9 / 71.8 = 6.8x mean advantage
    return ratios


__all__ = ["CONFIGS", "SEARCH", "VARIANTS", "fig11", "fig12", "fig12_search",
           "fig13", "fig14_variants", "fig15_race", "layer_cycles"]
