"""Benchmark orchestrator: one section per paper figure + ours.

``PYTHONPATH=src python -m benchmarks.run [--only fig11,...]``
Prints ``name,us_per_call,derived`` CSV lines.

With ``REPRO_CACHE_DIR`` set, every compile goes through the disk artifact
store; ``--expect-store-hits`` makes a warm re-run *assert* it recompiled
nothing (exit 1 on any store miss) — the CI warm-sweep check.

``--emit-json PATH`` additionally writes a machine-readable benchmark
snapshot: every emitted row plus a **cycle trajectory** — the analytic
cycle count of every Table-2 layer on every evaluation target at full
optimization, and their geomean.  Cycles are deterministic compiler
*output quality*, not wall time, so the snapshot is comparable across
machines; ``--baseline PATH [--max-regression 0.05]`` turns it into the
CI ``bench-trajectory`` gate: fail if the geomean cycles regress more
than 5% against the committed baseline (improvements always pass and
print so the baseline can be re-pinned).  ``--workers N`` shards the
trajectory sweep across worker processes via ``repro.sweep``.
"""
from __future__ import annotations

import argparse
import json
import math
import statistics
import sys
import time

TRAJECTORY_TARGETS = ("hvx", "dnnweaver")


def cycle_trajectory(emit, workers: int = 1) -> dict:
    """{'LAYER@target': cycles} for every paper layer at full optimization
    — the perf-gate metric, computed through the sweep coordinator."""
    import repro
    from benchmarks.paper_figs import CONFIGS
    from repro.core import library

    report = repro.sweep([s.key for s in library.PAPER_LAYERS],
                         TRAJECTORY_TARGETS,
                         options=CONFIGS["+vec+pack+unroll"],
                         workers=workers)
    cycles = {f"{r.layer}@{r.target}": r.cycles for r in report.ok}
    expect = len(library.PAPER_LAYERS) * len(TRAJECTORY_TARGETS)
    if len(cycles) != expect:
        print(f"FAIL: trajectory sweep incomplete: {report.summary()}",
              file=sys.stderr)
        sys.exit(1)
    c = report.counts()
    emit(f"trajectory/sweep,0,{c['units']} units ({c['compiled']} compiled, "
         f"{c['dedup'] + c['store'] + c['cache']} warm)")
    return cycles


def geomean(values) -> float:
    return math.exp(statistics.mean(math.log(max(v, 1e-9))
                                    for v in values))


def check_baseline(snapshot: dict, baseline_path: str,
                   max_regression: float) -> int:
    """Compare the trajectory geomean (and per-layer worst case) against a
    committed baseline snapshot; returns the number of gate failures.

    Both geomeans are computed over the *intersection* of layer keys, so
    adding/removing a paper layer shifts neither side of the ratio — the
    gate only ever measures the compiler on layers both runs compiled."""
    with open(baseline_path, "r", encoding="utf-8") as f:
        base = json.load(f)
    failures = 0
    shared = sorted(set(snapshot["cycles"]) & set(base.get("cycles", {})))
    if not shared:
        print(f"FAIL: no shared trajectory layers with {baseline_path} — "
              f"re-pin the baseline", file=sys.stderr)
        return 1
    dropped = len(snapshot["cycles"]) - len(shared)
    if dropped:
        print(f"trajectory/layer_set,0,{dropped} layer(s) not in the "
              f"baseline excluded from the gate (re-pin to include)")
    new_g = geomean(snapshot["cycles"][k] for k in shared)
    old_g = geomean(base["cycles"][k] for k in shared)
    ratio = new_g / old_g
    print(f"trajectory/geomean,0,cycles={new_g:.1f} baseline={old_g:.1f} "
          f"ratio=x{ratio:.4f} over {len(shared)} shared layers")
    if ratio > 1 + max_regression:
        print(f"FAIL: geomean cycles regressed x{ratio:.4f} "
              f"(> {1 + max_regression:.2f}) vs {baseline_path}",
              file=sys.stderr)
        failures += 1
    worst_key, worst = None, 0.0
    for k in shared:
        r = snapshot["cycles"][k] / base["cycles"][k] - 1
        if r > worst:
            worst_key, worst = k, r
    if worst_key is not None:
        print(f"trajectory/worst_layer,0,{worst_key}=+{worst * 100:.1f}%")
    return failures


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of {fig11,fig12,fig12s,fig13,fig14,"
                         "fig15,roofline,kernels,trajectory}")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--expect-store-hits", action="store_true",
                    help="fail unless every compile was a disk-store hit "
                         "(requires REPRO_CACHE_DIR and a prior warm run)")
    ap.add_argument("--emit-json", default=None, metavar="PATH",
                    help="write rows + the cycle trajectory as JSON")
    ap.add_argument("--baseline", default=None, metavar="PATH",
                    help="committed BENCH_*.json to gate the trajectory "
                         "geomean against")
    ap.add_argument("--max-regression", type=float, default=0.05,
                    help="allowed geomean cycle regression (default 5%%)")
    ap.add_argument("--workers", type=int, default=1,
                    help="shard the trajectory sweep across N worker "
                         "processes (repro.sweep)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    rows = []

    def emit(line: str) -> None:
        rows.append(line)
        print(line, flush=True)

    emit("name,us_per_call,derived")
    t0 = time.time()
    if only is None or "fig11" in only:
        from benchmarks.paper_figs import fig11
        fig11(emit)
    if only is None or "fig12" in only:
        from benchmarks.paper_figs import fig12
        fig12(emit)
    if only is None or "fig12s" in only:
        from benchmarks.paper_figs import fig12_search
        fig12_search(emit)
    if only is None or "fig13" in only:
        from benchmarks.paper_figs import fig13
        fig13(emit)
    if only is None or "fig14" in only:
        from benchmarks.paper_figs import fig14_variants
        fig14_variants(emit, workers=args.workers)
    if only is None or "fig15" in only:
        from benchmarks.paper_figs import fig15_race
        fig15_race(emit, workers=args.workers)
    if only is None or "kernels" in only:
        from benchmarks.kernels_bench import run as krun
        krun(emit)
    if only is None or "roofline" in only:
        from benchmarks.roofline_table import table
        table(emit, args.dryrun_dir)

    snapshot = None
    if args.emit_json or args.baseline or (only and "trajectory" in only):
        cycles = cycle_trajectory(emit, workers=args.workers)
        snapshot = {
            "schema": 1,
            "targets": list(TRAJECTORY_TARGETS),
            "cycles": cycles,
            "geomean_cycles": geomean(cycles.values()),
        }
    emit(f"benchmarks/total_wall,{(time.time() - t0) * 1e6:.0f},done")

    import repro
    stats = repro.cache_stats()
    emit(f"benchmarks/store,0,hits={stats['store_hits']} "
         f"misses={stats['store_misses']}")

    failures = 0
    if args.expect_store_hits:
        if stats["store_misses"] or not stats["store_hits"]:
            print(f"FAIL: expected an all-hit warm store sweep, got "
                  f"{stats['store_hits']} hits / "
                  f"{stats['store_misses']} misses", file=sys.stderr)
            failures += 1
        else:
            emit(f"benchmarks/store_warm,0,all {stats['store_hits']} "
                 f"compiles served from the artifact store")
    if args.baseline and snapshot is not None:
        failures += check_baseline(snapshot, args.baseline,
                                   args.max_regression)
    if args.emit_json and snapshot is not None:
        snapshot["rows"] = rows
        with open(args.emit_json, "w", encoding="utf-8") as f:
            json.dump(snapshot, f, indent=1)
        print(f"wrote {args.emit_json}", file=sys.stderr)
    if failures:
        sys.exit(1)


if __name__ == "__main__":
    main()
