"""Benchmark orchestrator: one section per paper figure + ours.

``PYTHONPATH=src python -m benchmarks.run [--only fig11,...]``
Prints ``name,us_per_call,derived`` CSV lines.
"""
from __future__ import annotations

import argparse
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of {fig11,fig12,fig13,roofline,kernels}")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def emit(line: str) -> None:
        print(line, flush=True)

    emit("name,us_per_call,derived")
    t0 = time.time()
    if only is None or "fig11" in only:
        from benchmarks.paper_figs import fig11
        fig11(emit)
    if only is None or "fig12" in only:
        from benchmarks.paper_figs import fig12
        fig12(emit)
    if only is None or "fig12s" in only:
        from benchmarks.paper_figs import fig12_search
        fig12_search(emit)
    if only is None or "fig13" in only:
        from benchmarks.paper_figs import fig13
        fig13(emit)
    if only is None or "kernels" in only:
        from benchmarks.kernels_bench import run as krun
        krun(emit)
    if only is None or "roofline" in only:
        from benchmarks.roofline_table import table
        table(emit, args.dryrun_dir)
    emit(f"benchmarks/total_wall,{(time.time() - t0) * 1e6:.0f},done")


if __name__ == "__main__":
    main()
