"""Benchmark orchestrator: one section per paper figure + ours.

``PYTHONPATH=src python -m benchmarks.run [--only fig11,...]``
Prints ``name,us_per_call,derived`` CSV lines.

With ``REPRO_CACHE_DIR`` set, every compile goes through the disk artifact
store; ``--expect-store-hits`` makes a warm re-run *assert* it recompiled
nothing (exit 1 on any store miss) — the CI warm-sweep check.
"""
from __future__ import annotations

import argparse
import sys
import time


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--only", default=None,
                    help="comma list of {fig11,fig12,fig12s,fig13,fig14,"
                         "roofline,kernels}")
    ap.add_argument("--dryrun-dir", default="results/dryrun")
    ap.add_argument("--expect-store-hits", action="store_true",
                    help="fail unless every compile was a disk-store hit "
                         "(requires REPRO_CACHE_DIR and a prior warm run)")
    args = ap.parse_args()
    only = set(args.only.split(",")) if args.only else None

    def emit(line: str) -> None:
        print(line, flush=True)

    emit("name,us_per_call,derived")
    t0 = time.time()
    if only is None or "fig11" in only:
        from benchmarks.paper_figs import fig11
        fig11(emit)
    if only is None or "fig12" in only:
        from benchmarks.paper_figs import fig12
        fig12(emit)
    if only is None or "fig12s" in only:
        from benchmarks.paper_figs import fig12_search
        fig12_search(emit)
    if only is None or "fig13" in only:
        from benchmarks.paper_figs import fig13
        fig13(emit)
    if only is None or "fig14" in only:
        from benchmarks.paper_figs import fig14_variants
        fig14_variants(emit)
    if only is None or "kernels" in only:
        from benchmarks.kernels_bench import run as krun
        krun(emit)
    if only is None or "roofline" in only:
        from benchmarks.roofline_table import table
        table(emit, args.dryrun_dir)
    emit(f"benchmarks/total_wall,{(time.time() - t0) * 1e6:.0f},done")

    import repro
    stats = repro.cache_stats()
    emit(f"benchmarks/store,0,hits={stats['store_hits']} "
         f"misses={stats['store_misses']}")
    if args.expect_store_hits:
        if stats["store_misses"] or not stats["store_hits"]:
            print(f"FAIL: expected an all-hit warm store sweep, got "
                  f"{stats['store_hits']} hits / "
                  f"{stats['store_misses']} misses", file=sys.stderr)
            sys.exit(1)
        emit(f"benchmarks/store_warm,0,all {stats['store_hits']} "
             f"compiles served from the artifact store")


if __name__ == "__main__":
    main()
