"""The 40-cell roofline table (§Roofline): reads results/dryrun/*.json."""
from __future__ import annotations

import glob
import json
import os

from repro import configs
from repro.roofline import model_flops, param_count, roofline_terms


def load_records(out_dir: str = "results/dryrun") -> dict:
    recs = {}
    for path in glob.glob(os.path.join(out_dir, "*.json")):
        r = json.load(open(path))
        recs[(r["arch"], r["shape"], r["mesh"])] = r
    return recs


def cell_row(r: dict) -> dict:
    rl = roofline_terms(r)
    cfg = configs.get_config(r["arch"])
    shape = configs.SHAPES[r["shape"]]
    mf = model_flops(cfg, shape, r["kind"]) / r["n_devices"]
    return {
        "arch": r["arch"], "shape": r["shape"], "mesh": r["mesh"],
        "compute_ms": rl.compute_s * 1e3,
        "memory_ms": rl.memory_s * 1e3,
        "collective_ms": rl.collective_s * 1e3,
        "bottleneck": rl.bottleneck,
        "compute_frac": rl.compute_fraction,
        "model_hlo_ratio": mf / max(r["flops"], 1e-9),
        "hbm_gib": r["bytes_per_device"] / 2**30,
        "compile_s": r["compile_s"],
    }


def table(emit, out_dir: str = "results/dryrun", mesh: str = "single"):
    recs = load_records(out_dir)
    rows = []
    for arch, shape, ok, why in configs.cells(include_skipped=True):
        r = recs.get((arch, shape, mesh))
        if r is None:
            emit(f"roofline/{arch}/{shape},0,MISSING")
            continue
        if r.get("status") == "skipped":
            emit(f"roofline/{arch}/{shape},0,skipped")
            continue
        row = cell_row(r)
        rows.append(row)
        emit(f"roofline/{arch}/{shape},0,"
             f"bottleneck={row['bottleneck']} "
             f"frac={row['compute_frac']:.3f} "
             f"c={row['compute_ms']:.1f}ms m={row['memory_ms']:.1f}ms "
             f"x={row['collective_ms']:.1f}ms hbm={row['hbm_gib']:.1f}GiB "
             f"useful={row['model_hlo_ratio']:.2f}")
    if rows:
        import statistics
        emit(f"roofline/mean_compute_frac,0,"
             f"{statistics.mean(r['compute_frac'] for r in rows):.3f}")
    return rows


__all__ = ["cell_row", "load_records", "table"]


def markdown(out_dir: str = "results/dryrun", mesh: str = "single") -> str:
    """Render the roofline table as GitHub markdown (EXPERIMENTS.md)."""
    recs = load_records(out_dir)
    lines = [
        "| arch | shape | compute | memory | collective | bottleneck | "
        "compute-frac | MODEL/HLO | HBM/dev | compile |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for arch, shape, ok, why in configs.cells(include_skipped=True):
        r = recs.get((arch, shape, mesh))
        if r is None or r.get("status") == "skipped":
            lines.append(f"| {arch} | {shape} | — | — | — | *skipped* | — | — "
                         f"| — |")
            continue
        row = cell_row(r)
        lines.append(
            f"| {arch} | {shape} | {row['compute_ms']:.1f} ms "
            f"| {row['memory_ms']:.1f} ms | {row['collective_ms']:.1f} ms "
            f"| {row['bottleneck']} | {row['compute_frac']:.3f} "
            f"| {row['model_hlo_ratio']:.2f} | {row['hbm_gib']:.1f} GiB "
            f"| {row['compile_s']:.0f} s |")
    return "\n".join(lines)
