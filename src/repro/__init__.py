"""repro — reproduction of "Restoring the Broken Covenant Between Compilers
and Deep Learning Accelerators".

Top-level API (the unified compile driver):

    import repro
    from repro.core import library

    art = repro.compile(library.gemm(16, 32, 24), target="hvx")
    art.run(inputs)        # execute the macro-mnemonic stream
    art.cycles()           # mnemonic-faithful analytic cycles
    art.listing()          # mnemonic program listing

Heavier subsystems (``repro.kernels``, ``repro.models``, ``repro.launch``,
...) depend on jax and are imported on demand — importing ``repro`` itself
only pulls in the numpy-based Covenant core.
"""
from repro.core.driver import (ArtifactStore, CompiledArtifact,
                               SearchOptions, SearchResult,
                               available_targets, cache_stats, clear_cache,
                               compile, compile_many, register_target)
from repro.core.pipeline import CompileOptions, Pipeline

__all__ = [
    "ArtifactStore", "CompileOptions", "CompiledArtifact", "Pipeline",
    "SearchOptions", "SearchResult", "available_targets", "cache_stats",
    "clear_cache", "compile", "compile_many", "register_target",
]
