"""repro — reproduction of "Restoring the Broken Covenant Between Compilers
and Deep Learning Accelerators".

Top-level API (the unified compile driver):

    import repro
    from repro.core import library

    art = repro.compile(library.gemm(16, 32, 24), target="hvx")
    art.run(inputs)        # execute the macro-mnemonic stream
    art.cycles()           # mnemonic-faithful analytic cycles
    art.listing()          # mnemonic program listing

Targets are addressable by string name everywhere (``repro.targets``:
bundled covenant specs, ``register``-ed ones, and derived variants like
``"dnnweaver@pe=32x32"``); accelerators are *defined* as declarative
specs (``repro.acg_spec`` / ``repro.ACGSpec``) and validated with
``repro.validate_spec`` / ``repro.check_covenant``.

Heavier subsystems (``repro.kernels``, ``repro.models``, ``repro.launch``,
...) depend on jax and are imported on demand — importing ``repro`` itself
only pulls in the numpy-based Covenant core.
"""
from repro.core.covenant import CovenantError, check_covenant, validate_acg
from repro.core.driver import (ArtifactStore, CompiledArtifact,
                               SearchOptions, SearchResult,
                               available_targets, cache_stats, clear_cache,
                               compile, compile_key, compile_many,
                               register_target)
from repro.core.pipeline import CompileOptions, Pipeline
from repro.core.spec import ACGSpec, SpecError, acg_spec, validate_spec
from repro.core.store import WarmStartIndex
from repro.core.sweep import SweepReport, sweep


def __getattr__(name: str):
    # ``repro.targets`` (the string-addressable registry facade) is served
    # lazily so ``python -m repro.targets`` does not double-import it.
    # (``repro.sweep`` needs no such hook: the function imported above is
    # the attribute, and the ``repro/sweep.py`` facade module that
    # ``python -m repro.sweep`` / an explicit submodule import rebinds it
    # to is itself callable.)
    if name == "targets":
        import repro.targets as targets
        return targets
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = [
    "ACGSpec", "ArtifactStore", "CompileOptions", "CompiledArtifact",
    "CovenantError", "Pipeline", "SearchOptions", "SearchResult",
    "SpecError", "SweepReport", "acg_spec", "available_targets",
    "WarmStartIndex", "cache_stats", "check_covenant", "clear_cache",
    "compile", "compile_key", "compile_many", "register_target", "sweep",
    "targets", "validate_acg", "validate_spec",
]
