"""Int8 gradient compression with error feedback.

Models the compressed gradient exchange used at scale: gradients are
quantised to int8 with a per-tensor scale before the (implicit, GSPMD)
all-reduce, and the quantisation residual is carried to the next step
(error feedback), which keeps SGD convergence unbiased in expectation.

``int8_compressed(opt)`` wraps any Optimizer: its state grows an ``err``
tree.  ``compress``/``decompress`` are also exported standalone — the
shard_map collective demo in runtime/collectives.py uses them around an
explicit ``psum`` to show the wire format.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .adamw import Optimizer


def compress(g: jax.Array) -> tuple[jax.Array, jax.Array]:
    """f32 tensor -> (int8 payload, f32 scale)."""
    gf = g.astype(jnp.float32)
    scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def decompress(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def int8_compressed(opt: Optimizer) -> Optimizer:
    def init(params):
        inner = opt.init(params)
        err = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"inner": inner, "err": err}

    def update(grads, state, params):
        def q_with_feedback(g, e):
            corrected = g.astype(jnp.float32) + e
            q, scale = compress(corrected)
            deq = decompress(q, scale)
            return deq, corrected - deq

        pairs = jax.tree.map(q_with_feedback, grads, state["err"])
        deq = jax.tree.map(lambda t: t[0], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
        err = jax.tree.map(lambda t: t[1], pairs,
                           is_leaf=lambda x: isinstance(x, tuple))
        new_params, inner, metrics = opt.update(deq, state["inner"], params)
        return new_params, {"inner": inner, "err": err}, metrics

    return Optimizer(init, update)


__all__ = ["compress", "decompress", "int8_compressed"]
