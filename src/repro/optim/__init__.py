from .adamw import (Optimizer, adamw, clip_by_global_norm, cosine_schedule,
                    global_norm, linear_schedule)
from .compression import int8_compressed

__all__ = ["Optimizer", "adamw", "clip_by_global_norm", "cosine_schedule",
           "global_norm", "int8_compressed", "linear_schedule"]
