"""Self-contained AdamW + schedules + global-norm clipping (no optax).

Moments are stored in f32 regardless of param dtype (bf16-safe), and the
optimizer-state pytree mirrors the param tree so the runtime's sharding
rules apply verbatim — sharding the moments over the ``data`` axis on top
of the param sharding is what gives ZeRO-style partitioned optimizer state.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import jax
import jax.numpy as jnp


def global_norm(tree) -> jax.Array:
    leaves = jax.tree.leaves(tree)
    return jnp.sqrt(sum(jnp.sum(jnp.square(l.astype(jnp.float32)))
                        for l in leaves))


def clip_by_global_norm(tree, max_norm: float):
    g = global_norm(tree)
    scale = jnp.minimum(1.0, max_norm / jnp.maximum(g, 1e-9))
    return jax.tree.map(lambda l: (l.astype(jnp.float32) * scale
                                   ).astype(l.dtype), tree), g


def cosine_schedule(peak_lr: float, warmup: int, total: int,
                    floor: float = 0.1) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        cos = peak_lr * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(math.pi * t)))
        return jnp.where(step < warmup, warm, cos)
    return lr


def linear_schedule(peak_lr: float, warmup: int, total: int) -> Callable:
    def lr(step):
        step = jnp.asarray(step, jnp.float32)
        warm = peak_lr * step / max(warmup, 1)
        t = jnp.clip((step - warmup) / max(total - warmup, 1), 0.0, 1.0)
        return jnp.where(step < warmup, warm, peak_lr * (1 - t))
    return lr


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init: Callable
    update: Callable  # (grads, state, params) -> (new_params, new_state)


def adamw(lr: Callable | float, b1: float = 0.9, b2: float = 0.95,
          eps: float = 1e-8, weight_decay: float = 0.1,
          max_grad_norm: float = 1.0) -> Optimizer:
    lr_fn = lr if callable(lr) else (lambda _: lr)

    def init(params):
        zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
        return {
            "mu": jax.tree.map(zeros, params),
            "nu": jax.tree.map(zeros, params),
            "step": jnp.zeros((), jnp.int32),
        }

    def update(grads, state, params):
        step = state["step"] + 1
        grads, gnorm = clip_by_global_norm(grads, max_grad_norm)
        b1c = 1 - b1 ** step.astype(jnp.float32)
        b2c = 1 - b2 ** step.astype(jnp.float32)
        lr_t = lr_fn(step)

        def upd(g, m, v, p):
            gf = g.astype(jnp.float32)
            m2 = b1 * m + (1 - b1) * gf
            v2 = b2 * v + (1 - b2) * jnp.square(gf)
            mhat = m2 / b1c
            vhat = v2 / b2c
            delta = mhat / (jnp.sqrt(vhat) + eps)
            if p.ndim >= 2:  # decay matrices only (norms/bias exempt)
                delta = delta + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr_t * delta).astype(p.dtype), m2, v2

        out = jax.tree.map(upd, grads, state["mu"], state["nu"], params)
        new_params = jax.tree.map(lambda t: t[0], out,
                                  is_leaf=lambda x: isinstance(x, tuple))
        new_mu = jax.tree.map(lambda t: t[1], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_nu = jax.tree.map(lambda t: t[2], out,
                              is_leaf=lambda x: isinstance(x, tuple))
        new_state = {"mu": new_mu, "nu": new_nu, "step": step}
        return new_params, new_state, {"grad_norm": gnorm, "lr": lr_t}

    return Optimizer(init, update)


__all__ = ["Optimizer", "adamw", "clip_by_global_norm", "cosine_schedule",
           "global_norm", "linear_schedule"]
