"""Checkpointing: atomic npz shards, keep-k retention, elastic reshard.

Layout:  <dir>/step_<n>/arrays.npz + manifest.json, written to a tmp dir
and ``os.replace``d into place (atomic on POSIX), so a crash mid-write can
never leave a half checkpoint that resume would pick up.

``restore_sharded`` re-places loaded host arrays onto an arbitrary mesh
with arbitrary shardings — checkpoints written on a (16,16) mesh restore
onto (2,16,16), (4,8) or a single CPU device unchanged (elastic scaling):
the on-disk format is mesh-free (full arrays), and placement happens at
load via ``jax.device_put`` with the new NamedSharding.
"""
from __future__ import annotations

import json
import os
import re
import shutil
import tempfile

import jax
import numpy as np

_SEP = "|"


def _flatten(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = _SEP.join(_key_str(k) for k in path)
        flat[key] = np.asarray(jax.device_get(leaf))
    return flat


def _key_str(k) -> str:
    if hasattr(k, "key"):
        return str(k.key)
    if hasattr(k, "idx"):
        return f"#{k.idx}"
    return str(k)


def _unflatten(like, flat: dict[str, np.ndarray]):
    paths_and_leaves, treedef = jax.tree_util.tree_flatten_with_path(like)
    leaves = []
    for path, leaf in paths_and_leaves:
        key = _SEP.join(_key_str(k) for k in path)
        if key not in flat:
            raise KeyError(f"checkpoint missing array {key!r}")
        arr = flat[key]
        want = getattr(leaf, "shape", None)
        if want is not None and tuple(arr.shape) != tuple(want):
            raise ValueError(f"{key}: checkpoint shape {arr.shape} != "
                             f"expected {want}")
        leaves.append(arr)
    return jax.tree_util.tree_unflatten(treedef, leaves)


def save_checkpoint(ckpt_dir: str, step: int, tree, extra: dict | None = None,
                    keep: int = 3) -> str:
    """Atomically write ``tree`` (params/opt state/...) for ``step``."""
    os.makedirs(ckpt_dir, exist_ok=True)
    final = os.path.join(ckpt_dir, f"step_{step:08d}")
    tmp = tempfile.mkdtemp(dir=ckpt_dir, prefix=".tmp_")
    try:
        flat = _flatten(tree)
        np.savez(os.path.join(tmp, "arrays.npz"), **flat)
        manifest = {"step": step, "n_arrays": len(flat),
                    "extra": extra or {}}
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        if os.path.exists(final):
            shutil.rmtree(final)
        os.replace(tmp, final)
    except BaseException:
        shutil.rmtree(tmp, ignore_errors=True)
        raise
    _gc(ckpt_dir, keep)
    return final


def _gc(ckpt_dir: str, keep: int) -> None:
    steps = sorted(_list_steps(ckpt_dir))
    for s in steps[:-keep] if keep > 0 else []:
        shutil.rmtree(os.path.join(ckpt_dir, f"step_{s:08d}"),
                      ignore_errors=True)


def _list_steps(ckpt_dir: str) -> list[int]:
    if not os.path.isdir(ckpt_dir):
        return []
    out = []
    for name in os.listdir(ckpt_dir):
        m = re.fullmatch(r"step_(\d+)", name)
        if m and os.path.exists(os.path.join(ckpt_dir, name, "manifest.json")):
            out.append(int(m.group(1)))
    return out


def latest_step(ckpt_dir: str) -> int | None:
    steps = _list_steps(ckpt_dir)
    return max(steps) if steps else None


def load_checkpoint(ckpt_dir: str, like, step: int | None = None):
    """Load into host numpy arrays shaped like ``like``.  Returns
    (tree, step, extra)."""
    step = latest_step(ckpt_dir) if step is None else step
    if step is None:
        raise FileNotFoundError(f"no checkpoints in {ckpt_dir}")
    path = os.path.join(ckpt_dir, f"step_{step:08d}")
    with np.load(os.path.join(path, "arrays.npz")) as z:
        flat = {k: z[k] for k in z.files}
    with open(os.path.join(path, "manifest.json")) as f:
        manifest = json.load(f)
    return _unflatten(like, flat), step, manifest.get("extra", {})


def restore_sharded(ckpt_dir: str, like, shardings, step: int | None = None):
    """Elastic restore: place arrays with the provided shardings (which may
    correspond to a completely different mesh than the one that saved)."""
    host_tree, step, extra = load_checkpoint(ckpt_dir, like, step)
    placed = jax.tree.map(
        lambda arr, leaf, sh: jax.device_put(
            np.asarray(arr, dtype=getattr(leaf, "dtype", arr.dtype)), sh),
        host_tree, like, shardings)
    return placed, step, extra


__all__ = ["latest_step", "load_checkpoint", "restore_sharded",
           "save_checkpoint"]
