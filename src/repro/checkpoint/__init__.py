from .store import (latest_step, load_checkpoint, restore_sharded,
                    save_checkpoint)

__all__ = ["latest_step", "load_checkpoint", "restore_sharded",
           "save_checkpoint"]
