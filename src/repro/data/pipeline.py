"""Deterministic synthetic LM data pipeline.

Produces a learnable token stream (order-2 mixture process: each token
depends on the previous token plus a slowly varying "topic"), packed into
fixed-length sequences with EOS boundaries.  Deterministic in
(seed, step, host): every host generates only its shard of the global
batch — the host-sharded layout a multi-pod data loader needs.  Includes
stub-frontend extras (patch/frame embeddings) keyed off the same stream so
VLM/audio batches are reproducible too.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass
class SyntheticLM:
    vocab: int
    seq_len: int
    global_batch: int
    seed: int = 0
    n_hosts: int = 1
    host_id: int = 0
    eos: int = 1
    extras: dict = dataclasses.field(default_factory=dict)
    # extras: name -> (shape_fn(batch, seq), np_dtype)

    def __post_init__(self):
        assert self.global_batch % self.n_hosts == 0
        self.host_batch = self.global_batch // self.n_hosts
        # fixed mixing tables make the stream learnable (not iid noise)
        rng = np.random.default_rng(self.seed)
        self._shift = rng.integers(1, self.vocab - 1)
        self._topic_period = 97

    def _sequence(self, step: int, row: int) -> np.ndarray:
        """One packed sequence: documents of random length, EOS-separated."""
        gidx = step * self.global_batch + self.host_id * self.host_batch + row
        rng = np.random.default_rng((self.seed, gidx))
        out = np.empty(self.seq_len + 1, np.int32)
        pos = 0
        while pos < self.seq_len + 1:
            doc_len = int(rng.integers(16, max(17, self.seq_len // 2)))
            tok = int(rng.integers(2, self.vocab))
            topic = int(rng.integers(2, self.vocab))
            n = min(doc_len, self.seq_len + 1 - pos)
            for i in range(n):
                out[pos + i] = tok
                nxt = (tok * 3 + topic + (i % self._topic_period)) % self.vocab
                noise = int(rng.integers(0, 4))
                tok = nxt if noise else int(rng.integers(2, self.vocab))
            pos += n
            if pos < self.seq_len + 1:
                out[pos] = self.eos
                pos += 1
        return out[: self.seq_len + 1]

    def batch(self, step: int) -> dict:
        seqs = np.stack([self._sequence(step, r)
                         for r in range(self.host_batch)])
        tokens = seqs[:, :-1]
        targets = seqs[:, 1:]
        weights = (targets != self.eos).astype(np.float32)
        out = {"tokens": tokens, "targets": targets, "weights": weights}
        rng = np.random.default_rng((self.seed, step, self.host_id, 7))
        for name, (shape_fn, dtype) in self.extras.items():
            shp = shape_fn(self.host_batch, self.seq_len)
            out[name] = rng.standard_normal(shp).astype(np.float32)
        return out

    def __iter__(self):
        step = 0
        while True:
            yield self.batch(step)
            step += 1


def make_batch_specs(cfg, batch: int, seq: int, extras: dict | None = None):
    """jax.ShapeDtypeStruct specs for a train batch (dry-run input_specs)."""
    import jax
    import jax.numpy as jnp

    specs = {
        "tokens": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "targets": jax.ShapeDtypeStruct((batch, seq), jnp.int32),
        "weights": jax.ShapeDtypeStruct((batch, seq), jnp.float32),
    }
    for name, (shape_fn, dtype) in (extras or {}).items():
        specs[name] = jax.ShapeDtypeStruct(shape_fn(batch, seq), dtype)
    return specs


__all__ = ["SyntheticLM", "make_batch_specs"]
