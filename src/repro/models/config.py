"""Architecture configuration shared by every model family."""
from __future__ import annotations

import dataclasses


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    name: str
    family: str                    # dense | moe | hybrid | vlm | ssm | audio
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab: int
    head_dim: int = 0              # 0 -> d_model // n_heads

    # normalization / attention details
    norm: str = "rmsnorm"          # rmsnorm | layernorm
    qk_norm: bool = False          # qwen3 per-head RMS on q/k
    rope_frac: float = 1.0         # stablelm: partial rotary (0.25)
    rope_theta: float = 10_000.0
    window: int = 0                # sliding-window size (0 = full)
    local_global: tuple[int, int] = (0, 0)  # gemma3: (5 local, 1 global)
    logit_softcap: float = 0.0
    mlp: str = "swiglu"            # swiglu | geglu | gelu
    parallel_block: bool = False   # command-r style parallel attn+mlp
    tie_embeddings: bool = False
    embed_scale: bool = False      # gemma: scale embeds by sqrt(d_model)

    # MoE
    n_experts: int = 0
    n_shared_experts: int = 0
    top_k: int = 0
    moe_d_ff: int = 0              # routed-expert hidden dim
    first_dense: int = 0           # deepseek: leading dense layers
    capacity_factor: float = 1.25
    # dispatch token-block size: the sort-based dispatch processes tokens in
    # blocks of this many (global) tokens, bounding the (E, C, d) buffers —
    # without it a 1M-token prefill materialises ~100 GiB of dispatch state.
    moe_block_tokens: int = 32_768

    # SSM (mamba2 / zamba2 mamba blocks)
    ssm_state: int = 0
    ssm_headdim: int = 64
    ssm_expand: int = 2
    ssm_ngroups: int = 1
    ssm_conv: int = 4
    ssm_chunk: int = 256

    # hybrid (zamba2): one shared transformer block every N mamba blocks
    shared_attn_every: int = 0
    lora_rank: int = 0

    # enc-dec (whisper)
    enc_layers: int = 0
    enc_frames: int = 0            # stub conv-frontend output length
    # vlm (paligemma)
    vis_tokens: int = 0
    vis_dim: int = 0

    # numerics / execution
    param_dtype: str = "bfloat16"
    compute_dtype: str = "bfloat16"
    remat: bool = True
    attn_impl: str = "auto"        # dense | blocked | auto (seq-dependent)
    attn_block_q: int = 512
    attn_block_kv: int = 1024

    @property
    def hd(self) -> int:
        return self.head_dim or (self.d_model // max(self.n_heads, 1))

    @property
    def ssm_d_inner(self) -> int:
        return self.ssm_expand * self.d_model

    @property
    def ssm_nheads(self) -> int:
        return self.ssm_d_inner // self.ssm_headdim

    def layer_groups(self) -> tuple[int, int]:
        """(n_groups, layers_per_group) for the grouped layer scan."""
        local, glob = self.local_global
        per = (local + glob) if (local + glob) > 0 else 1
        if self.family == "hybrid" and self.shared_attn_every:
            per = self.shared_attn_every
        n = self.n_layers - self.first_dense
        assert n % per == 0, (self.name, n, per)
        return n // per, per

    def replace(self, **kw) -> "ArchConfig":
        return dataclasses.replace(self, **kw)


__all__ = ["ArchConfig"]
