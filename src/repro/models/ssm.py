"""Mamba2 (SSD) block — chunked jnp implementation + one-token decode.

``ssd_chunked`` is the pure-jnp twin of ``kernels/ssd_scan.py`` (same chunk
decomposition; a single ``lax.scan`` over chunks carries the inter-chunk
state while doing the quadratic intra-chunk work as chunk-local GEMMs), so
it lowers under pjit for the 32k/500k dry-runs with O(S·chunk) memory.
On TPU the Pallas kernel replaces the intra-chunk stage.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import cdt, dense_init, keygen, pdt
from .config import ArchConfig


# ---------------------------------------------------------------------------
# chunked SSD (sequence parallel within chunk, scan across chunks)
# ---------------------------------------------------------------------------


def ssd_chunked(x, dt, A, B, C, *, chunk: int,
                init_state: jax.Array | None = None):
    """x (b,s,h,p), dt (b,s,h) (>0), A (h,) (<0), B/C (b,s,g,n).
    Returns (y (b,s,h,p) f32, final_state (b,h,n,p) f32)."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    ck = min(chunk, s)
    spad = -(-s // ck) * ck
    if spad != s:
        pad = [(0, 0), (0, spad - s)] + [(0, 0)] * (x.ndim - 2)
        x = jnp.pad(x, pad)
        dt = jnp.pad(dt, [(0, 0), (0, spad - s), (0, 0)])
        B = jnp.pad(B, [(0, 0), (0, spad - s), (0, 0), (0, 0)])
        C = jnp.pad(C, [(0, 0), (0, spad - s), (0, 0), (0, 0)])
    nck = spad // ck
    # xs stay in the INPUT dtype and B/C stay UN-repeated (b,s,g,n): folding
    # the group->head repeat into the scan inputs would materialise
    # rep x (671 MB for mamba2's g=1, h=80) of f32 per layer; instead the
    # grouped einsums below broadcast over the head-repeat dim ``r``.
    xr = x.reshape(b, nck, ck, g, rep, p)
    dtr = dt.astype(jnp.float32).reshape(b, nck, ck, g, rep)
    Br = B.reshape(b, nck, ck, g, n)
    Cr = C.reshape(b, nck, ck, g, n)
    Af = A.astype(jnp.float32).reshape(g, rep)

    ii = jnp.arange(ck)[:, None]
    jj = jnp.arange(ck)[None, :]
    tril = jj <= ii

    def chunk_step(h_prev, inp):
        xc, dtc, bc, cc = inp      # (b,ck,g,r,p), (b,ck,g,r), (b,ck,g,n) x2
        xc = xc.astype(jnp.float32)
        bc = bc.astype(jnp.float32)
        cc = cc.astype(jnp.float32)
        da = dtc * Af              # (b,ck,g,r)
        cum = jnp.cumsum(da, axis=1)
        seg = cum[:, :, None] - cum[:, None]                # (b,l,m,g,r)
        # mask INSIDE the exp: where(mask, exp(seg), 0) leaks inf gradients
        # through the masked branch when seg > 0 (upper triangle)
        gamma = jnp.exp(jnp.where(tril[None, :, :, None, None], seg, -1e30))
        xdt = xc * dtc[..., None]
        cb = jnp.einsum("blgn,bmgn->blmg", cc, bc)          # per group
        att = cb[..., None] * gamma                         # (b,l,m,g,r)
        y_intra = jnp.einsum("blmgr,bmgrp->blgrp", att, xdt)
        # inter-chunk contribution from the incoming state
        gamma_in = jnp.exp(cum)                             # (b,l,g,r)
        y_inter = jnp.einsum("blgn,bgrnp->blgrp", cc, h_prev) * \
            gamma_in[..., None]
        # end-of-chunk state
        decay_end = jnp.exp(cum[:, -1:] - cum)              # (b,l,g,r)
        state = jnp.einsum("blgn,blgrp->bgrnp", bc,
                           xdt * decay_end[..., None])
        h_new = jnp.exp(cum[:, -1])[..., None, None] * h_prev + state
        return h_new, y_intra + y_inter

    h0 = jnp.zeros((b, g, rep, n, p), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32).reshape(b, g, rep, n, p)
    xs = (xr.swapaxes(0, 1), dtr.swapaxes(0, 1), Br.swapaxes(0, 1),
          Cr.swapaxes(0, 1))
    h_fin, ys = jax.lax.scan(chunk_step, h0, xs)
    y = ys.swapaxes(0, 1).reshape(b, spad, h, p)[:, :s]
    return y, h_fin.reshape(b, h, n, p)


# ---------------------------------------------------------------------------
# Mamba2 block (in_proj -> conv -> SSD -> gated norm -> out_proj)
# ---------------------------------------------------------------------------


def init_mamba_block(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    d, di = cfg.d_model, cfg.ssm_d_inner
    g, n, hh = cfg.ssm_ngroups, cfg.ssm_state, cfg.ssm_nheads
    conv_ch = di + 2 * g * n
    dtype = pdt(cfg)
    return {
        "in_proj": dense_init(next(ks), (d, 2 * di + 2 * g * n + hh), dtype),
        "conv_w": dense_init(next(ks), (cfg.ssm_conv, conv_ch), dtype),
        "conv_b": jnp.zeros((conv_ch,), dtype),
        "A_log": jnp.log(jnp.linspace(1.0, 16.0, hh)).astype(jnp.float32),
        "D": jnp.ones((hh,), jnp.float32),
        "dt_bias": jnp.zeros((hh,), jnp.float32),
        "norm_scale": jnp.ones((di,), dtype),
        "out_proj": dense_init(next(ks), (di, d), dtype),
    }


def _split_proj(cfg: ArchConfig, zxbcdt: jax.Array):
    di, g, n, hh = (cfg.ssm_d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                    cfg.ssm_nheads)
    z, x, bc, dt = jnp.split(zxbcdt, [di, 2 * di, 2 * di + 2 * g * n], -1)
    return z, x, bc, dt


def _gated_norm(y: jax.Array, z: jax.Array, scale: jax.Array,
                eps: float = 1e-6) -> jax.Array:
    """Gated RMS: stats in f32, IO in z's dtype (bf16-safe)."""
    yf = y.astype(jnp.float32) * jax.nn.silu(z.astype(jnp.float32))
    ms = jnp.mean(jnp.square(yf), -1, keepdims=True)
    out = yf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
    return out.astype(z.dtype)


def mamba_block(cfg: ArchConfig, p: dict, u: jax.Array) -> jax.Array:
    """Full-sequence mamba2 block.  u: (b, s, d_model)."""
    b, s, d = u.shape
    di, g, n, hh, hp = (cfg.ssm_d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                        cfg.ssm_nheads, cfg.ssm_headdim)
    zxbcdt = u @ p["in_proj"].astype(u.dtype)
    z, xbc_x, bc, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xbc_x, bc], -1)      # conv input (b,s,conv_ch)
    # depthwise causal conv, width ssm_conv — IO in compute dtype (a 4-tap
    # conv is bf16-safe); keeping these (B,S,ch) surfaces out of f32 halves
    # the dominant HBM traffic of the block
    w = p["conv_w"].astype(u.dtype)
    xp = jnp.pad(xbc, [(0, 0), (cfg.ssm_conv - 1, 0), (0, 0)])
    conv = sum(xp[:, i:i + s] * w[i] for i in range(cfg.ssm_conv))
    conv = jax.nn.silu(conv + p["conv_b"].astype(u.dtype))
    x, B, C = jnp.split(conv, [di, di + g * n], -1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, _ = ssd_chunked(x.reshape(b, s, hh, hp), dtv, A,
                       B.reshape(b, s, g, n), C.reshape(b, s, g, n),
                       chunk=cfg.ssm_chunk)
    y = y + x.reshape(b, s, hh, hp) * p["D"][None, None, :, None]
    y = _gated_norm(y.reshape(b, s, di), z, p["norm_scale"])
    return y @ p["out_proj"].astype(y.dtype)


# -- decode ------------------------------------------------------------------


def init_mamba_cache(cfg: ArchConfig, batch: int, dtype=jnp.float32) -> dict:
    di, g, n, hh, hp = (cfg.ssm_d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                        cfg.ssm_nheads, cfg.ssm_headdim)
    conv_ch = di + 2 * g * n
    return {
        "conv": jnp.zeros((batch, cfg.ssm_conv - 1, conv_ch), jnp.float32),
        "ssm": jnp.zeros((batch, hh, n, hp), jnp.float32),
    }


def mamba_block_decode(cfg: ArchConfig, p: dict, u: jax.Array,
                       cache: dict) -> tuple[jax.Array, dict]:
    """One token.  u: (b, d_model); cache: {conv (b,w-1,ch), ssm (b,h,n,p)}."""
    b, d = u.shape
    di, g, n, hh, hp = (cfg.ssm_d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                        cfg.ssm_nheads, cfg.ssm_headdim)
    zxbcdt = u @ p["in_proj"].astype(u.dtype)
    z, xbc_x, bc, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xbc_x, bc], -1).astype(jnp.float32)
    hist = jnp.concatenate([cache["conv"], xbc[:, None]], 1)  # (b,w,ch)
    w = p["conv_w"].astype(jnp.float32)
    conv = jax.nn.silu(jnp.einsum("bwc,wc->bc", hist, w) +
                       p["conv_b"].astype(jnp.float32))
    x, B, C = jnp.split(conv, [di, di + g * n], -1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])  # (b,hh)
    A = -jnp.exp(p["A_log"])
    xh = x.reshape(b, hh, hp)
    Bh = jnp.repeat(B.reshape(b, g, n), hh // g, 1)
    Ch = jnp.repeat(C.reshape(b, g, n), hh // g, 1)
    decay = jnp.exp(A[None] * dtv)                        # (b,hh)
    ssm = cache["ssm"] * decay[..., None, None] + \
        jnp.einsum("bhn,bhp->bhnp", Bh, xh * dtv[..., None])
    y = jnp.einsum("bhnp,bhn->bhp", ssm, Ch) + xh * p["D"][None, :, None]
    y = _gated_norm(y.reshape(b, di), z, p["norm_scale"])
    out = (y @ p["out_proj"].astype(jnp.float32)).astype(u.dtype)
    return out, {"conv": hist[:, 1:], "ssm": ssm}


__all__ = ["init_mamba_block", "init_mamba_cache", "mamba_block",
           "mamba_block_decode", "ssd_chunked"]
