"""Zamba2 hybrid: Mamba2 backbone with a *shared* transformer block.

Every ``shared_attn_every`` mamba blocks, one shared attention+MLP block
runs on ``concat(hidden, embed0)`` (width 2·d_model).  The block's weights
are a single copy reused at every invocation; each invocation adds its own
low-rank (LoRA) adapter — the Zamba2 paper's parameter-sharing scheme.  Its
output projects back to d_model and adds to the residual stream.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .common import (apply_norm, apply_rope, cdt, cross_entropy, dense_init,
                     embed_tokens, init_embed, init_norm, keygen,
                     logits_from_hidden, pdt, rope_frequencies, shard_act)
from .config import ArchConfig
from .ssm import (init_mamba_block, init_mamba_cache, mamba_block,
                  mamba_block_decode)


# ---------------------------------------------------------------------------
# shared attention block (width 2*d_model) + per-use LoRA
# ---------------------------------------------------------------------------


def _shared_dims(cfg: ArchConfig) -> tuple[int, int, int]:
    da = 2 * cfg.d_model                 # concat width
    hd = da // cfg.n_heads
    return da, hd, cfg.d_ff


def init_shared_block(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    da, hd, ff = _shared_dims(cfg)
    dtype = pdt(cfg)
    return {
        "ln": {"scale": jnp.ones((da,), dtype)},
        "wq": dense_init(next(ks), (da, cfg.n_heads * hd), dtype),
        "wk": dense_init(next(ks), (da, cfg.n_kv_heads * hd), dtype),
        "wv": dense_init(next(ks), (da, cfg.n_kv_heads * hd), dtype),
        "wo": dense_init(next(ks), (cfg.n_heads * hd, cfg.d_model), dtype),
        "wi": dense_init(next(ks), (da, ff), dtype),
        "wg": dense_init(next(ks), (da, ff), dtype),
        "wo_mlp": dense_init(next(ks), (ff, cfg.d_model), dtype),
    }


def init_lora(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    da, hd, ff = _shared_dims(cfg)
    r = cfg.lora_rank
    dtype = pdt(cfg)
    return {
        "qa": dense_init(next(ks), (da, r), dtype),
        "qb": jnp.zeros((r, cfg.n_heads * hd), dtype),
        "ia": dense_init(next(ks), (da, r), dtype),
        "ib": jnp.zeros((r, ff), dtype),
    }


def _rms(x, scale, eps=1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt(jnp.mean(xf * xf, -1, keepdims=True) + eps)
            * scale.astype(jnp.float32)).astype(x.dtype)


def shared_block_qkv(cfg, sp, lora, h):
    """h: (B,S,2D) -> q,k,v heads."""
    b, s, _ = h.shape
    da, hd, _ = _shared_dims(cfg)
    wq = sp["wq"].astype(h.dtype)
    q = h @ wq + (h @ lora["qa"].astype(h.dtype)) @ lora["qb"].astype(h.dtype)
    k = h @ sp["wk"].astype(h.dtype)
    v = h @ sp["wv"].astype(h.dtype)
    q = q.reshape(b, s, cfg.n_heads, hd).transpose(0, 2, 1, 3)
    k = k.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    v = v.reshape(b, s, cfg.n_kv_heads, hd).transpose(0, 2, 1, 3)
    return q, k, v


def shared_block(cfg: ArchConfig, sp: dict, lora: dict, x: jax.Array,
                 embed0: jax.Array, positions: jax.Array) -> jax.Array:
    """Full-sequence shared block; returns the d_model residual update."""
    h = jnp.concatenate([x, embed0], -1)
    h = _rms(h, sp["ln"]["scale"])
    q, k, v = shared_block_qkv(cfg, sp, lora, h)
    b, s, _ = h.shape
    da, hd, _ = _shared_dims(cfg)
    fn = attn_mod.select_attention(cfg, s)
    o = fn(q, k, v, causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * hd)
    a = o @ sp["wo"].astype(h.dtype)
    mi = h @ sp["wi"].astype(h.dtype) + \
        (h @ lora["ia"].astype(h.dtype)) @ lora["ib"].astype(h.dtype)
    m = (jax.nn.silu(mi) * (h @ sp["wg"].astype(h.dtype))) @ \
        sp["wo_mlp"].astype(h.dtype)
    return a + m


# ---------------------------------------------------------------------------
# model
# ---------------------------------------------------------------------------


def init_params(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    n_groups, per = cfg.layer_groups()   # per = shared_attn_every

    def group(k):
        gks = jax.random.split(k, per + 1)
        mambas = [{"ln": init_norm(cfg), "mamba": init_mamba_block(cfg, gk)}
                  for gk in gks[:per]]
        return mambas, init_lora(cfg, gks[-1])

    mamba_layers, loras = jax.vmap(group)(jax.random.split(next(ks), n_groups))
    return {
        "embed": init_embed(cfg, next(ks)),
        "layers": mamba_layers,          # list of per trees, stacked groups
        "loras": loras,                  # stacked (n_groups, ...)
        "shared": init_shared_block(cfg, next(ks)),
        "ln_f": init_norm(cfg),
    }


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = embed_tokens(cfg, params["embed"], tokens)
    embed0 = x
    positions = jnp.arange(tokens.shape[1])

    def group_body(x, xs):
        mambas, lora = xs
        x = shard_act(x, ("batch", "seq", None))
        x = x + shared_block(cfg, params["shared"], lora, x, embed0,
                             positions)
        for j in range(len(mambas)):
            lp = mambas[j]
            h = apply_norm(cfg, lp["ln"], x)
            x = x + mamba_block(cfg, lp["mamba"], h)
        return x, None

    body = jax.checkpoint(group_body, prevent_cse=False) if cfg.remat \
        else group_body
    x, _ = jax.lax.scan(lambda c, p: body(c, p), x,
                        (params["layers"], params["loras"]))
    return apply_norm(cfg, params["ln_f"], x)


def loss_fn(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    h = forward(cfg, params, batch["tokens"])
    logits = logits_from_hidden(cfg, params["embed"], h)
    return cross_entropy(logits, batch["targets"], batch.get("weights"))


# -- serving -----------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    dtype = dtype or cdt(cfg)
    n_groups, per = cfg.layer_groups()
    da, hd, _ = _shared_dims(cfg)
    m1 = init_mamba_cache(cfg, batch)
    mamba = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None, None],
                                   (n_groups, per) + a.shape).copy(), m1)
    return {
        "mamba": mamba,
        "attn": {
            "k": jnp.zeros((n_groups, batch, cfg.n_kv_heads, max_len, hd),
                           dtype),
            "v": jnp.zeros((n_groups, batch, cfg.n_kv_heads, max_len, hd),
                           dtype),
        },
        "length": jnp.zeros((batch,), jnp.int32),
    }


def _shared_prefill(cfg, sp, lora, x, embed0, positions, kv):
    from .transformer import _cache_write_prefill
    b, s, _ = x.shape
    h = jnp.concatenate([x, embed0], -1)
    h = _rms(h, sp["ln"]["scale"])
    q, k, v = shared_block_qkv(cfg, sp, lora, h)
    fn = attn_mod.select_attention(cfg, s)
    o = fn(q, k, v, causal=True)
    da, hd, _ = _shared_dims(cfg)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * hd)
    a = o @ sp["wo"].astype(h.dtype)
    mi = h @ sp["wi"].astype(h.dtype) + \
        (h @ lora["ia"].astype(h.dtype)) @ lora["ib"].astype(h.dtype)
    m = (jax.nn.silu(mi) * (h @ sp["wg"].astype(h.dtype))) @ \
        sp["wo_mlp"].astype(h.dtype)
    nkv = {"k": _cache_write_prefill(kv["k"], k, s),
           "v": _cache_write_prefill(kv["v"], v, s)}
    return a + m, nkv


def prefill(cfg: ArchConfig, params: dict, tokens: jax.Array, cache: dict
            ) -> tuple[jax.Array, dict]:
    from .mamba import prefill as _  # noqa: F401 (doc pointer)
    x = embed_tokens(cfg, params["embed"], tokens)
    embed0 = x
    b, s, _ = x.shape
    positions = jnp.arange(s)
    n_groups, per = cfg.layer_groups()

    def group_body(x, xs):
        mambas, lora, kv_in, mcache_in = xs
        upd, nkv = _shared_prefill(cfg, params["shared"], lora, x, embed0,
                                   positions, kv_in)
        x = x + upd
        msts = []
        for j in range(per):
            lp = mambas[j]
            h = apply_norm(cfg, lp["ln"], x)
            y, st = _mamba_prefill_states(cfg, lp["mamba"], h)
            x = x + y
            msts.append(st)
        mst = jax.tree.map(lambda *a: jnp.stack(a), *msts)
        return x, (nkv, mst)

    x, (kv_new, m_new) = jax.lax.scan(
        group_body, x,
        (params["layers"], params["loras"], cache["attn"], cache["mamba"]))
    h = apply_norm(cfg, params["ln_f"], x[:, -1:])
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0]
    return logits, {"mamba": m_new, "attn": kv_new,
                    "length": cache["length"] + s}


def _mamba_prefill_states(cfg, p, h):
    """mamba_block + final (conv, ssm) states (shared with mamba.prefill)."""
    from .ssm import _gated_norm, _split_proj, ssd_chunked
    b, s, _ = h.shape
    di, g, n, hh, hp = (cfg.ssm_d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                        cfg.ssm_nheads, cfg.ssm_headdim)
    zxbcdt = h @ p["in_proj"].astype(h.dtype)
    z, xbc_x, bc, dt = _split_proj(cfg, zxbcdt)
    xbc = jnp.concatenate([xbc_x, bc], -1)
    w = p["conv_w"].astype(jnp.float32)
    xp = jnp.pad(xbc.astype(jnp.float32),
                 [(0, 0), (cfg.ssm_conv - 1, 0), (0, 0)])
    conv = sum(xp[:, i:i + s] * w[i] for i in range(cfg.ssm_conv))
    conv = jax.nn.silu(conv + p["conv_b"].astype(jnp.float32))
    xin, B, C = jnp.split(conv, [di, di + g * n], -1)
    dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
    A = -jnp.exp(p["A_log"])
    y, st = ssd_chunked(xin.reshape(b, s, hh, hp), dtv, A,
                        B.reshape(b, s, g, n), C.reshape(b, s, g, n),
                        chunk=cfg.ssm_chunk)
    y = y + xin.reshape(b, s, hh, hp) * p["D"][None, None, :, None]
    y = _gated_norm(y.reshape(b, s, di), z, p["norm_scale"])
    out = (y @ p["out_proj"].astype(jnp.float32)).astype(h.dtype)
    conv_state = xbc.astype(jnp.float32)[:, s - (cfg.ssm_conv - 1):]
    return out, {"conv": conv_state, "ssm": st}


def decode_step(cfg: ArchConfig, params: dict, tokens: jax.Array,
                cache: dict) -> tuple[jax.Array, dict]:
    from .transformer import _cache_write_token
    x = embed_tokens(cfg, params["embed"], tokens[:, None])
    embed0 = x
    length = cache["length"]
    b = tokens.shape[0]
    n_groups, per = cfg.layer_groups()
    da, hd, _ = _shared_dims(cfg)

    def group_body(x, xs):
        mambas, lora, kv_in, mst_in = xs
        h = jnp.concatenate([x, embed0], -1)
        h = _rms(h, params["shared"]["ln"]["scale"])
        q, k, v = shared_block_qkv(cfg, params["shared"], lora, h)
        ck = _cache_write_token(kv_in["k"], k[:, :, 0], length)
        cv = _cache_write_token(kv_in["v"], v[:, :, 0], length)
        o = attn_mod.decode_attention(q[:, :, 0], ck, cv, length + 1)
        a = o.reshape(b, 1, cfg.n_heads * hd) @ \
            params["shared"]["wo"].astype(h.dtype)
        mi = h @ params["shared"]["wi"].astype(h.dtype) + \
            (h @ lora["ia"].astype(h.dtype)) @ lora["ib"].astype(h.dtype)
        m = (jax.nn.silu(mi) * (h @ params["shared"]["wg"].astype(h.dtype))
             ) @ params["shared"]["wo_mlp"].astype(h.dtype)
        x = x + a + m
        msts = []
        for j in range(per):
            lp = mambas[j]
            hn = apply_norm(cfg, lp["ln"], x)[:, 0]
            st_j = jax.tree.map(lambda s_: s_[j], mst_in)
            y, st2 = mamba_block_decode(cfg, lp["mamba"], hn, st_j)
            x = x + y[:, None]
            msts.append(st2)
        mst = jax.tree.map(lambda *arrs: jnp.stack(arrs), *msts)
        return x, ({"k": ck, "v": cv}, mst)

    x, (kv_new, m_new) = jax.lax.scan(
        group_body, x,
        (params["layers"], params["loras"], cache["attn"], cache["mamba"]))
    h = apply_norm(cfg, params["ln_f"], x)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0]
    return logits, {"mamba": m_new, "attn": kv_new, "length": length + 1}


__all__ = ["decode_step", "forward", "init_cache", "init_params", "loss_fn",
           "prefill"]
