"""Mixture-of-Experts LM (deepseek-moe fine-grained w/ shared experts,
olmoe).

The MoE FFN uses sort-based expert dispatch: tokens' top-k assignments are
sorted by expert, packed into a capacity-bounded (E, C, d) buffer (overflow
dropped — GShard semantics), pushed through per-expert GEMMs via a batched
einsum, and scattered back weighted by router probabilities.  Under pjit
with experts sharded over the ``model`` axis this lowers to exactly the
all-to-all dispatch pattern of expert parallelism.

Router runs in f32; aux load-balancing loss follows Switch (mean fraction x
mean probability, scaled by E).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer as dense
from .common import (apply_mlp, apply_norm, cdt, cross_entropy, dense_init,
                     embed_tokens, init_embed, init_mlp, init_norm, keygen,
                     logits_from_hidden, pdt, shard_act)
from .config import ArchConfig

# ---------------------------------------------------------------------------
# MoE FFN
# ---------------------------------------------------------------------------


def init_moe_ffn(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    d, e, ff = cfg.d_model, cfg.n_experts, cfg.moe_d_ff or cfg.d_ff
    dtype = pdt(cfg)
    p = {
        "router": dense_init(next(ks), (d, e), jnp.float32),
        "wi": dense_init(next(ks), (e, d, ff), dtype),
        "wg": dense_init(next(ks), (e, d, ff), dtype),
        "wo": dense_init(next(ks), (e, ff, d), dtype),
    }
    if cfg.n_shared_experts:
        p["shared"] = init_mlp(cfg, next(ks),
                               d_ff=(cfg.moe_d_ff or cfg.d_ff) *
                               cfg.n_shared_experts)
    return p


def _dispatch_block(cfg: ArchConfig, p: dict, xf: jax.Array) -> jax.Array:
    """Sort-based dispatch + expert GEMMs for one token block (Tb, D)."""
    t, d = xf.shape
    e, k = cfg.n_experts, cfg.top_k
    logits = (xf.astype(jnp.float32) @ p["router"])          # (Tb,E) f32
    probs = jax.nn.softmax(logits, -1)
    gate, eidx = jax.lax.top_k(probs, k)                     # (Tb,k)
    gate = gate / jnp.maximum(gate.sum(-1, keepdims=True), 1e-9)

    cap = int(cfg.capacity_factor * t * k / e)
    cap = max(8, -(-cap // 8) * 8)
    flat_e = eidx.reshape(-1)                                # (Tb*k,)
    flat_t = jnp.repeat(jnp.arange(t), k)
    flat_g = gate.reshape(-1)
    order = jnp.argsort(flat_e, stable=True)
    se, st_, sg = flat_e[order], flat_t[order], flat_g[order]
    # rank within expert group = position - start offset of that expert
    counts = jnp.bincount(se, length=e)
    starts = jnp.cumsum(counts) - counts
    rank = jnp.arange(t * k) - starts[se]
    keep = rank < cap
    slot_e = jnp.where(keep, se, e)          # overflow -> dropped row
    slot_c = jnp.where(keep, rank, 0)

    buf = jnp.zeros((e + 1, cap, d), cdt(cfg))
    buf = buf.at[slot_e, slot_c].set(xf[st_].astype(cdt(cfg)))
    h = jnp.einsum("ecd,edf->ecf", buf[:e], p["wi"].astype(cdt(cfg)))
    g = jnp.einsum("ecd,edf->ecf", buf[:e], p["wg"].astype(cdt(cfg)))
    h = jax.nn.silu(h) * g
    yexp = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(cdt(cfg)))
    # gather back + weighted combine
    gathered = yexp[jnp.minimum(slot_e, e - 1), slot_c]      # (Tb*k, d)
    gathered = jnp.where(keep[:, None], gathered, 0.0)
    contrib = gathered.astype(jnp.float32) * sg[:, None]
    return jnp.zeros((t, d), jnp.float32).at[st_].add(contrib)


def moe_ffn(cfg: ArchConfig, p: dict, x: jax.Array) -> tuple[jax.Array, jax.Array]:
    """x: (B,S,D) -> (out, aux_loss).  Tokens are dispatched in blocks of
    ``cfg.moe_block_tokens`` so dispatch state stays bounded at any prompt
    length (GShard-style grouping)."""
    b, s, d = x.shape
    e, k = cfg.n_experts, cfg.top_k
    t = b * s
    xf = x.reshape(t, d)

    # Switch aux loss over ALL tokens (cheap: logits only)
    logits = (xf.astype(jnp.float32) @ p["router"])
    probs = jax.nn.softmax(logits, -1)
    _, eidx = jax.lax.top_k(probs, k)
    frac = jnp.mean(jax.nn.one_hot(eidx, e, dtype=jnp.float32), axis=(0, 1))
    aux = e * jnp.sum(frac * jnp.mean(probs, 0)) * k

    tb = min(cfg.moe_block_tokens, t)
    if t % tb != 0:
        tb = t  # fallback: single block (tiny inputs)
    if tb == t:
        out = _dispatch_block(cfg, p, xf)
    else:
        blocks = xf.reshape(t // tb, tb, d)

        def step(_, blk):
            return None, _dispatch_block(cfg, p, blk)

        _, outs = jax.lax.scan(step, None, blocks)
        out = outs.reshape(t, d)

    if cfg.n_shared_experts:
        out = out + apply_mlp(cfg, p["shared"], xf).astype(jnp.float32)
    return out.reshape(b, s, d).astype(x.dtype), aux


# ---------------------------------------------------------------------------
# model = dense transformer with MoE FFN (first_dense leading dense layers)
# ---------------------------------------------------------------------------


def init_layer(cfg: ArchConfig, key, moe: bool) -> dict:
    ks = keygen(key)
    return {
        "ln1": init_norm(cfg),
        "attn": dense.init_attn(cfg, next(ks)),
        "ln2": init_norm(cfg),
        "ffn": init_moe_ffn(cfg, next(ks)) if moe else init_mlp(cfg, next(ks)),
    }


def init_params(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    n_groups, per = cfg.layer_groups()
    assert per == 1, "moe family scans single layers"

    def group(k):
        return [init_layer(cfg, k, moe=True)]

    layers = jax.vmap(group)(jax.random.split(next(ks), n_groups))
    p = {
        "embed": init_embed(cfg, next(ks)),
        "layers": layers,
        "ln_f": init_norm(cfg),
    }
    if cfg.first_dense:
        dk = jax.random.split(next(ks), cfg.first_dense)
        p["dense_layers"] = [init_layer(cfg, kk, moe=False) for kk in dk]
    return p


def _moe_layer(cfg: ArchConfig, lp: dict, x: jax.Array, positions,
               moe: bool) -> tuple[jax.Array, jax.Array]:
    h = apply_norm(cfg, lp["ln1"], x)
    a = dense.attention_block(cfg, lp["attn"], h, local=False,
                              positions=positions)
    x = x + a
    h = apply_norm(cfg, lp["ln2"], x)
    if moe:
        y, aux = moe_ffn(cfg, lp["ffn"], h)
    else:
        y, aux = apply_mlp(cfg, lp["ffn"], h), jnp.float32(0)
    return x + y, aux


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array
            ) -> tuple[jax.Array, jax.Array]:
    x = embed_tokens(cfg, params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])
    aux_total = jnp.float32(0)
    for lp in params.get("dense_layers", []):
        x, _ = _moe_layer(cfg, lp, x, positions, moe=False)

    def group_body(carry, group_params):
        x, aux = carry
        x = shard_act(x, ("batch", "seq", None))
        x, a = _moe_layer(cfg, group_params[0], x, positions, moe=True)
        return (x, aux + a), None

    body = jax.checkpoint(group_body, prevent_cse=False) if cfg.remat \
        else group_body
    (x, aux_total), _ = jax.lax.scan(lambda c, p: body(c, p),
                                     (x, aux_total), params["layers"])
    return apply_norm(cfg, params["ln_f"], x), aux_total


def loss_fn(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    h, aux = forward(cfg, params, batch["tokens"])
    logits = logits_from_hidden(cfg, params["embed"], h)
    ce = cross_entropy(logits, batch["targets"], batch.get("weights"))
    return ce + 0.01 * aux / max(cfg.n_layers, 1)


# -- serving ------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or cdt(cfg)
    n_groups, _ = cfg.layer_groups()
    hkv, hd = cfg.n_kv_heads, cfg.hd
    c = {"layers": [{
        "k": jnp.zeros((n_groups, batch, hkv, max_len, hd), dtype),
        "v": jnp.zeros((n_groups, batch, hkv, max_len, hd), dtype),
    }], "length": jnp.zeros((batch,), jnp.int32)}
    if cfg.first_dense:
        c["dense"] = [{
            "k": jnp.zeros((batch, hkv, max_len, hd), dtype),
            "v": jnp.zeros((batch, hkv, max_len, hd), dtype),
        } for _ in range(cfg.first_dense)]
    return c


def _attn_prefill_cached(cfg, lp, x, positions, kv):
    from . import attention as attn_mod
    from .common import apply_rope, rope_frequencies
    b, s, _ = x.shape
    h = apply_norm(cfg, lp["ln1"], x)
    q, k, v = dense._qkv(cfg, lp["attn"], h)
    if cfg.rope_frac > 0:
        sin, cos = rope_frequencies(cfg, positions)
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
    fn = attn_mod.select_attention(cfg, s)
    o = fn(q, k, v, causal=True)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    a = o @ lp["attn"]["wo"].astype(x.dtype)
    new_kv = {"k": dense._cache_write_prefill(kv["k"], k, s),
              "v": dense._cache_write_prefill(kv["v"], v, s)}
    return x + a, h, new_kv


def prefill(cfg: ArchConfig, params: dict, tokens: jax.Array, cache: dict
            ) -> tuple[jax.Array, dict]:
    x = embed_tokens(cfg, params["embed"], tokens)
    positions = jnp.arange(tokens.shape[1])
    new_dense = []
    for lp, kv in zip(params.get("dense_layers", []), cache.get("dense", [])):
        x, _, nkv = _attn_prefill_cached(cfg, lp, x, positions, kv)
        h = apply_norm(cfg, lp["ln2"], x)
        x = x + apply_mlp(cfg, lp["ffn"], h)
        new_dense.append(nkv)

    def group_body(x, xs):
        group_params, kv_in = xs
        lp = group_params[0]
        x, _, nkv = _attn_prefill_cached(cfg, lp, x, positions, kv_in)
        h = apply_norm(cfg, lp["ln2"], x)
        y, _ = moe_ffn(cfg, lp["ffn"], h)
        return x + y, nkv

    x, kv_new = jax.lax.scan(group_body, x,
                             (params["layers"], cache["layers"][0]))
    h = apply_norm(cfg, params["ln_f"], x[:, -1:])
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0]
    out = {"layers": [kv_new], "length": cache["length"] + tokens.shape[1]}
    if new_dense:
        out["dense"] = new_dense
    return logits, out


def _attn_decode_cached(cfg, lp, x, length, kv):
    from . import attention as attn_mod
    from .common import apply_rope, rope_frequencies
    b = x.shape[0]
    h = apply_norm(cfg, lp["ln1"], x)
    q, k, v = dense._qkv(cfg, lp["attn"], h)
    if cfg.rope_frac > 0:
        sin, cos = rope_frequencies(cfg, length[:, None])
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
    ck = dense._cache_write_token(kv["k"], k[:, :, 0], length)
    cv = dense._cache_write_token(kv["v"], v[:, :, 0], length)
    o = attn_mod.decode_attention(q[:, :, 0], ck, cv, length + 1)
    a = o.reshape(b, 1, cfg.n_heads * cfg.hd) @ lp["attn"]["wo"].astype(x.dtype)
    return x + a, {"k": ck, "v": cv}


def decode_step(cfg: ArchConfig, params: dict, tokens: jax.Array,
                cache: dict) -> tuple[jax.Array, dict]:
    x = embed_tokens(cfg, params["embed"], tokens[:, None])
    length = cache["length"]
    new_dense = []
    for lp, kv in zip(params.get("dense_layers", []), cache.get("dense", [])):
        x, nkv = _attn_decode_cached(cfg, lp, x, length, kv)
        h = apply_norm(cfg, lp["ln2"], x)
        x = x + apply_mlp(cfg, lp["ffn"], h)
        new_dense.append(nkv)

    def group_body(x, xs):
        group_params, kv_in = xs
        lp = group_params[0]
        x, nkv = _attn_decode_cached(cfg, lp, x, length, kv_in)
        h = apply_norm(cfg, lp["ln2"], x)
        y, _ = moe_ffn(cfg, lp["ffn"], h)
        return x + y, nkv

    x, kv_new = jax.lax.scan(group_body, x,
                             (params["layers"], cache["layers"][0]))
    h = apply_norm(cfg, params["ln_f"], x)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0]
    out = {"layers": [kv_new], "length": length + 1}
    if new_dense:
        out["dense"] = new_dense
    return logits, out


__all__ = ["decode_step", "forward", "init_cache", "init_params", "loss_fn",
           "moe_ffn", "prefill"]
