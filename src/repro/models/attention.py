"""Attention implementations used by the model zoo.

Three compilable paths, all GQA-aware:

* ``dense_attention``   — einsum + masked softmax.  Exact, O(S^2) memory;
  used for short sequences and as the numeric baseline.
* ``blocked_attention`` — double ``lax.scan`` (q blocks x kv blocks) with
  online softmax: the pure-jnp twin of the Pallas flash kernel.  O(S·block)
  memory, so the 32k/500k dry-runs compile without materialising S^2.
  Sliding windows restrict the inner scan via a banded ``dynamic_slice``.
* ``decode_attention``  — one-token einsum vs a (possibly sharded) KV cache.

On a real TPU backend these dispatch to the Pallas kernels in
``repro.kernels`` (same BlockSpec geometry the Covenant tiler picked);
on CPU/dry-run they stay jnp so GSPMD can partition them.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _repeat_kv(k: jax.Array, hq: int) -> jax.Array:
    hkv = k.shape[1]
    return k if hkv == hq else jnp.repeat(k, hq // hkv, axis=1)


def _shard_heads(q, k, v):
    """Megatron-style head-parallel constraint (no-op unless the launcher
    configured activation sharding): move the model axis from the sequence
    dim onto heads before the attention math, so logits shard over heads
    instead of replicating."""
    from .common import shard_act

    spec = ("batch", "heads", None, None)
    return (shard_act(q, spec), shard_act(k, spec), shard_act(v, spec))


def dense_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, scale: float | None = None,
                    kv_len: jax.Array | None = None) -> jax.Array:
    """q (B,Hq,Sq,D), k/v (B,Hkv,Sk,D)."""
    b, hq, sq, d = q.shape
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    q, k, v = _shard_heads(q, k, v)
    sk = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    s = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    qpos = jnp.arange(sq)[:, None] + q_offset
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    m4 = mask[None, None]
    if kv_len is not None:
        m4 = m4 & (kpos[None, None, None] < kv_len[:, None, None, None])
    s = jnp.where(m4, s, NEG_INF)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)
                      ).astype(q.dtype)


def blocked_attention(q, k, v, *, causal: bool = True, window: int = 0,
                      q_offset: int = 0, scale: float | None = None,
                      block_q: int = 512, block_kv: int = 1024) -> jax.Array:
    """Flash-structured attention in pure jnp (scan over q and kv blocks)."""
    b, hq, sq, d = q.shape
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    q, k, v = _shard_heads(q, k, v)
    sk = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    bq = min(block_q, sq)
    bkv = min(block_kv, sk)
    nq = -(-sq // bq)
    nkv = -(-sk // bkv)
    sq_p, sk_p = nq * bq, nkv * bkv
    if sq_p != sq:
        q = jnp.pad(q, [(0, 0), (0, 0), (0, sq_p - sq), (0, 0)])
    if sk_p != sk:
        k = jnp.pad(k, [(0, 0), (0, 0), (0, sk_p - sk), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, 0), (0, sk_p - sk), (0, 0)])
    qb = q.reshape(b, hq, nq, bq, d).transpose(2, 0, 1, 3, 4)
    kb = k.reshape(b, hq, nkv, bkv, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, hq, nkv, bkv, d).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_q):
        qi, qblk = qi_q
        qf = qblk.astype(jnp.float32)

        def kv_step(carry, kj_kv):
            m_prev, l_prev, acc = carry
            kj, kblk, vblk = kj_kv
            s = jnp.einsum("bhqd,bhkd->bhqk", qf,
                           kblk.astype(jnp.float32)) * scale
            qpos = qi * bq + jnp.arange(bq)[:, None] + q_offset
            kpos = kj * bkv + jnp.arange(bkv)[None, :]
            mask = kpos < sk
            if causal:
                mask &= kpos <= qpos
            if window:
                mask &= kpos > qpos - window
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_cur = jnp.max(s, -1, keepdims=True)
            m_new = jnp.maximum(m_prev, m_cur)
            p = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p,
                                           vblk.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, hq, bq, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, hq, bq, 1), jnp.float32)
        a0 = jnp.zeros((b, hq, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(
            kv_step, (m0, l0, a0), (jnp.arange(nkv), kb, vb))
        out = acc / jnp.where(l == 0.0, 1.0, l)
        return None, out.astype(qblk.dtype)

    _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = ob.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq_p, d)
    return out[:, :, :sq]


# ---------------------------------------------------------------------------
# fused flash attention with custom VJP (memory-flat backward)
# ---------------------------------------------------------------------------
#
# Differentiating the double-scan blocked attention stores every kv-block's
# logits and mask for the backward (stacked (nkv, B, H, bq, bkv) f32 — tens
# of GiB at 4k seq on a 104B model).  The flash backward instead RECOMPUTES
# block logits from (q, k, v, out, lse): memory stays O(S·d), compute grows
# ~1.75x — exactly the Pallas kernel's behaviour on real TPUs.


def _fa_fwd_scan(q, k, v, causal, window, q_offset, scale, bq, bkv,
                 sk_true=None):
    """Returns (out (B,H,S,D), lse (B,H,S,1)); S,Sk already padded."""
    b, h, sq, d = q.shape
    sk = k.shape[2]
    nq, nkv = sq // bq, sk // bkv
    qb = q.reshape(b, h, nq, bq, d).transpose(2, 0, 1, 3, 4)
    kb = k.reshape(b, h, nkv, bkv, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, h, nkv, bkv, d).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_q):
        qi, qblk = qi_q
        qf = qblk.astype(jnp.float32)

        def kv_step(carry, kj_kv):
            m_prev, l_prev, acc = carry
            kj, kblk, vblk = kj_kv
            s = jnp.einsum("bhqd,bhkd->bhqk", qf,
                           kblk.astype(jnp.float32)) * scale
            mask = _block_mask(qi, kj, bq, bkv, q_offset, causal, window,
                               sk_true)
            s = jnp.where(mask[None, None], s, NEG_INF)
            m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
            p = jnp.where(mask[None, None], jnp.exp(s - m_new), 0.0)
            alpha = jnp.exp(m_prev - m_new)
            l_new = l_prev * alpha + p.sum(-1, keepdims=True)
            acc = acc * alpha + jnp.einsum("bhqk,bhkd->bhqd", p,
                                           vblk.astype(jnp.float32))
            return (m_new, l_new, acc), None

        m0 = jnp.full((b, h, bq, 1), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, h, bq, 1), jnp.float32)
        a0 = jnp.zeros((b, h, bq, d), jnp.float32)
        (m, l, acc), _ = jax.lax.scan(kv_step, (m0, l0, a0),
                                      (jnp.arange(nkv), kb, vb))
        lsafe = jnp.where(l == 0.0, 1.0, l)
        out = (acc / lsafe).astype(qblk.dtype)
        lse = m + jnp.log(lsafe)
        return None, (out, lse)

    _, (ob, lseb) = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = ob.transpose(1, 2, 0, 3, 4).reshape(b, h, sq, d)
    lse = lseb.transpose(1, 2, 0, 3, 4).reshape(b, h, sq, 1)
    return out, lse


def _block_mask(qi, kj, bq, bkv, q_offset, causal, window, sk_true=None):
    qpos = qi * bq + jnp.arange(bq)[:, None] + q_offset
    kpos = kj * bkv + jnp.arange(bkv)[None, :]
    mask = jnp.ones((bq, bkv), bool)
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    if sk_true is not None:
        mask &= kpos < sk_true
    return mask


import functools as _ft


@_ft.partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8, 9))
def _flash_core(q, k, v, causal, window, q_offset, scale, bq, bkv, sk_true):
    out, _ = _fa_fwd_scan(q, k, v, causal, window if window else None,
                          q_offset, scale, bq, bkv, sk_true)
    return out


def _flash_core_fwd(q, k, v, causal, window, q_offset, scale, bq, bkv,
                    sk_true):
    out, lse = _fa_fwd_scan(q, k, v, causal, window if window else None,
                            q_offset, scale, bq, bkv, sk_true)
    return out, (q, k, v, out, lse)


def _flash_core_bwd(causal, window, q_offset, scale, bq, bkv, sk_true,
                    res, dout):
    q, k, v, out, lse = res
    b, h, sq, d = q.shape
    sk = k.shape[2]
    nq, nkv = sq // bq, sk // bkv
    qf = q.astype(jnp.float32)
    doutf = dout.astype(jnp.float32)
    # D_i = rowsum(dout * out)
    delta = jnp.sum(doutf * out.astype(jnp.float32), -1, keepdims=True)

    kb = k.reshape(b, h, nkv, bkv, d).transpose(2, 0, 1, 3, 4)
    vb = v.reshape(b, h, nkv, bkv, d).transpose(2, 0, 1, 3, 4)
    qb = qf.reshape(b, h, nq, bq, d).transpose(2, 0, 1, 3, 4)
    dob = doutf.reshape(b, h, nq, bq, d).transpose(2, 0, 1, 3, 4)
    lseb = lse.reshape(b, h, nq, bq, 1).transpose(2, 0, 1, 3, 4)
    delb = delta.reshape(b, h, nq, bq, 1).transpose(2, 0, 1, 3, 4)

    def kv_step(dq_acc, kj_kv):
        kj, kblk, vblk = kj_kv
        kf = kblk.astype(jnp.float32)
        vf = vblk.astype(jnp.float32)

        def q_step(carry, qi_q):
            dkj, dvj = carry
            qi, qblk, doblk, lseblk, delblk = qi_q
            s = jnp.einsum("bhqd,bhkd->bhqk", qblk, kf) * scale
            mask = _block_mask(qi, kj, bq, bkv, q_offset, causal, window,
                               sk_true)
            p = jnp.where(mask[None, None], jnp.exp(s - lseblk), 0.0)
            dp = jnp.einsum("bhqd,bhkd->bhqk", doblk, vf)
            ds = p * (dp - delblk) * scale
            dq_blk = jnp.einsum("bhqk,bhkd->bhqd", ds, kf)
            dkj = dkj + jnp.einsum("bhqk,bhqd->bhkd", ds, qblk)
            dvj = dvj + jnp.einsum("bhqk,bhqd->bhkd", p, doblk)
            return (dkj, dvj), dq_blk

        z = jnp.zeros((b, h, bkv, d), jnp.float32)
        (dkj, dvj), dq_blocks = jax.lax.scan(
            q_step, (z, z), (jnp.arange(nq), qb, dob, lseb, delb))
        return dq_acc + dq_blocks, (dkj, dvj)

    dq0 = jnp.zeros((nq, b, h, bq, d), jnp.float32)
    dq_blocks, (dkb, dvb) = jax.lax.scan(kv_step, dq0,
                                         (jnp.arange(nkv), kb, vb))
    dq = dq_blocks.transpose(1, 2, 0, 3, 4).reshape(b, h, sq, d)
    dk = dkb.transpose(1, 2, 0, 3, 4).reshape(b, h, sk, d)
    dv = dvb.transpose(1, 2, 0, 3, 4).reshape(b, h, sk, d)
    return dq.astype(q.dtype), dk.astype(k.dtype), dv.astype(v.dtype)


_flash_core.defvjp(_flash_core_fwd, _flash_core_bwd)


def fused_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    q_offset: int = 0, scale: float | None = None,
                    block_q: int = 512, block_kv: int = 1024) -> jax.Array:
    """Flash attention with a recompute-based custom VJP — the jnp twin of
    the Pallas kernel, memory-flat through the backward."""
    b, hq, sq, d = q.shape
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    q, k, v = _shard_heads(q, k, v)
    sk = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    bq = min(block_q, sq)
    bkv = min(block_kv, sk)
    nq, nkv = -(-sq // bq), -(-sk // bkv)
    sq_p, sk_p = nq * bq, nkv * bkv
    if sq_p != sq:
        q = jnp.pad(q, [(0, 0), (0, 0), (0, sq_p - sq), (0, 0)])
    if sk_p != sk:
        k = jnp.pad(k, [(0, 0), (0, 0), (0, sk_p - sk), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, 0), (0, sk_p - sk), (0, 0)])
        # padded keys must be masked out: fold into causal/window via the
        # kv-length mask (kpos < sk is implied by causal when q end-aligned;
        # for safety, rely on q_offset alignment making padded kpos > qpos)
    out = _flash_core(q, k, v, causal, window, q_offset, scale, bq, bkv, sk)
    return out[:, :, :sq]


def sliding_attention(q, k, v, *, window: int, q_offset: int = 0,
                      scale: float | None = None,
                      block_q: int = 512) -> jax.Array:
    """Banded causal attention: each q block attends to a dynamic kv slice
    of length block_q + window.  O(S · window) compute AND memory — this is
    what makes gemma3 local layers / long_500k viable."""
    b, hq, sq, d = q.shape
    k = _repeat_kv(k, hq)
    v = _repeat_kv(v, hq)
    q, k, v = _shard_heads(q, k, v)
    sk = k.shape[2]
    scale = scale if scale is not None else d ** -0.5
    bq = min(block_q, sq)
    nq = -(-sq // bq)
    sq_p = nq * bq
    if sq_p != sq:
        q = jnp.pad(q, [(0, 0), (0, 0), (0, sq_p - sq), (0, 0)])
    span = bq + window  # kv slice covering the block's band
    qb = q.reshape(b, hq, nq, bq, d).transpose(2, 0, 1, 3, 4)

    def q_step(_, qi_q):
        qi, qblk = qi_q
        q_start = qi * bq + q_offset
        start = jnp.maximum(q_start - window, 0)
        start = jnp.minimum(start, jnp.maximum(sk - span, 0))
        ks = jax.lax.dynamic_slice_in_dim(k, start, min(span, sk), axis=2)
        vs = jax.lax.dynamic_slice_in_dim(v, start, min(span, sk), axis=2)
        s = jnp.einsum("bhqd,bhkd->bhqk", qblk.astype(jnp.float32),
                       ks.astype(jnp.float32)) * scale
        qpos = q_start + jnp.arange(bq)[:, None]
        kpos = start + jnp.arange(min(span, sk))[None, :]
        mask = (kpos <= qpos) & (kpos > qpos - window) & (kpos < sk)
        s = jnp.where(mask[None, None], s, NEG_INF)
        p = jax.nn.softmax(s, -1)
        p = jnp.where(mask[None, None], p, 0.0)
        out = jnp.einsum("bhqk,bhkd->bhqd", p, vs.astype(jnp.float32))
        return None, out.astype(qblk.dtype)

    _, ob = jax.lax.scan(q_step, None, (jnp.arange(nq), qb))
    out = ob.transpose(1, 2, 0, 3, 4).reshape(b, hq, sq_p, d)
    return out[:, :, :sq]


def decode_attention(q, k_cache, v_cache, kv_len, *, window: int = 0,
                     scale: float | None = None) -> jax.Array:
    """One new token vs the cache.  q (B,Hq,D), caches (B,Hkv,S,D),
    kv_len (B,) = number of valid entries INCLUDING the new token.

    Grouped-GQA form: q is reshaped to (B, Hkv, G, D) and the einsums keep
    the cache's native kv-head count — repeating kv to Hq would force GSPMD
    to re-shard a sequence-sharded cache onto heads (a full f32 all-gather
    of the cache per layer per token).  The tiny q/logits tensors replicate
    instead; softmax reductions over the sharded seq dim psum cheaply."""
    b, hq, d = q.shape
    hkv = k_cache.shape[1]
    g = hq // hkv
    qg = q.reshape(b, hkv, g, d)
    s = k_cache.shape[2]
    scale = scale if scale is not None else d ** -0.5
    logits = jnp.einsum("bhgd,bhkd->bhgk", qg.astype(jnp.float32),
                        k_cache.astype(jnp.float32)) * scale
    kpos = jnp.arange(s)[None, None, None]
    mask = kpos < kv_len[:, None, None, None]
    if window:
        mask &= kpos >= (kv_len[:, None, None, None] - window)
    logits = jnp.where(mask, logits, NEG_INF)
    p = jax.nn.softmax(logits, -1)
    out = jnp.einsum("bhgk,bhkd->bhgd", p, v_cache.astype(jnp.float32))
    return out.reshape(b, hq, d).astype(q.dtype)


def select_attention(cfg, sq: int):
    """auto: dense below 2k, blocked above (compile-safe for 32k/500k)."""
    impl = cfg.attn_impl
    if impl == "auto":
        impl = "dense" if sq <= 2048 else "fused"
    if impl == "dense":
        return functools.partial(dense_attention)
    if impl == "blocked":
        return functools.partial(blocked_attention, block_q=cfg.attn_block_q,
                                 block_kv=cfg.attn_block_kv)
    return functools.partial(fused_attention, block_q=cfg.attn_block_q,
                             block_kv=cfg.attn_block_kv)


__all__ = ["blocked_attention", "decode_attention", "dense_attention",
           "fused_attention", "select_attention", "sliding_attention"]
