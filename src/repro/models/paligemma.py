"""PaliGemma-style VLM: SigLIP frontend STUB + projector + gemma decoder.

The modality frontend is a stub per the assignment: ``input_specs`` provides
precomputed patch embeddings (B, vis_tokens, vis_dim).  The model owns the
linear projector (vis_dim -> d_model) and the MQA (kv=1) gemma decoder.
Image tokens form a prefix; text tokens follow (causal over the whole
stream — prefix-LM masking noted as a deviation in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import transformer as dense
from .common import (cdt, cross_entropy, dense_init, embed_tokens, keygen,
                     logits_from_hidden, pdt)
from .config import ArchConfig


def init_params(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    p = dense.init_params(cfg, next(ks))
    p["projector"] = dense_init(next(ks), (cfg.vis_dim, cfg.d_model), pdt(cfg))
    return p


def _embed_multimodal(cfg: ArchConfig, params: dict, tokens: jax.Array,
                      patches: jax.Array) -> jax.Array:
    """[image prefix | text] embedding stream."""
    img = patches.astype(cdt(cfg)) @ params["projector"].astype(cdt(cfg))
    txt = embed_tokens(cfg, params["embed"], tokens)
    return jnp.concatenate([img, txt], axis=1)


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array,
            patches: jax.Array) -> jax.Array:
    embeds = _embed_multimodal(cfg, params, tokens, patches)
    return dense.forward(cfg, params, tokens, embeds=embeds)


def loss_fn(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    """CE on the text positions only (image prefix carries no targets)."""
    h = forward(cfg, params, batch["tokens"], batch["patches"])
    n_img = batch["patches"].shape[1]
    h_txt = h[:, n_img:]
    logits = logits_from_hidden(cfg, params["embed"], h_txt)
    return cross_entropy(logits, batch["targets"], batch.get("weights"))


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    return dense.init_cache(cfg, batch, max_len, dtype)


def prefill(cfg: ArchConfig, params: dict, tokens: jax.Array, cache: dict,
            patches: jax.Array) -> tuple[jax.Array, dict]:
    embeds = _embed_multimodal(cfg, params, tokens, patches)
    return dense.prefill(cfg, params, tokens, cache, embeds=embeds)


def decode_step(cfg: ArchConfig, params: dict, tokens: jax.Array,
                cache: dict) -> tuple[jax.Array, dict]:
    return dense.decode_step(cfg, params, tokens, cache)


__all__ = ["decode_step", "forward", "init_cache", "init_params", "loss_fn",
           "prefill"]
