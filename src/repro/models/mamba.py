"""Pure Mamba2 (SSD) language model — attention-free, O(1)-state decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .common import (apply_norm, cdt, cross_entropy, embed_tokens,
                     init_embed, init_norm, keygen, logits_from_hidden,
                     shard_act)
from .config import ArchConfig
from .ssm import (init_mamba_block, init_mamba_cache, mamba_block,
                  mamba_block_decode)


def init_params(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    n_groups, per = cfg.layer_groups()
    assert per == 1

    def group(k):
        return [{"ln": init_norm(cfg), "mamba": init_mamba_block(cfg, k)}]

    layers = jax.vmap(group)(jax.random.split(next(ks), n_groups))
    return {"embed": init_embed(cfg, next(ks)), "layers": layers,
            "ln_f": init_norm(cfg)}


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array) -> jax.Array:
    x = embed_tokens(cfg, params["embed"], tokens)

    def group_body(x, gp):
        lp = gp[0]
        x = shard_act(x, ("batch", "seq", None))
        h = apply_norm(cfg, lp["ln"], x)
        return x + mamba_block(cfg, lp["mamba"], h), None

    body = jax.checkpoint(group_body, prevent_cse=False) if cfg.remat \
        else group_body
    x, _ = jax.lax.scan(lambda c, p: body(c, p), x, params["layers"])
    return apply_norm(cfg, params["ln_f"], x)


def loss_fn(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    h = forward(cfg, params, batch["tokens"])
    logits = logits_from_hidden(cfg, params["embed"], h)
    return cross_entropy(logits, batch["targets"], batch.get("weights"))


def init_cache(cfg: ArchConfig, batch: int, max_len: int = 0,
               dtype=None) -> dict:
    n_groups, _ = cfg.layer_groups()
    one = init_mamba_cache(cfg, batch)
    layers = jax.tree.map(
        lambda a: jnp.broadcast_to(a[None], (n_groups,) + a.shape), one)
    return {"layers": layers, "length": jnp.zeros((batch,), jnp.int32)}


def prefill(cfg: ArchConfig, params: dict, tokens: jax.Array, cache: dict
            ) -> tuple[jax.Array, dict]:
    """Run the prompt through the chunked SSD, materialising per-layer
    (conv, ssm) states for decode."""
    from .ssm import _gated_norm, _split_proj, ssd_chunked
    x = embed_tokens(cfg, params["embed"], tokens)
    b, s, _ = x.shape

    def group_body(x, xs):
        gp, _cache_in = xs
        lp = gp[0]
        h = apply_norm(cfg, lp["ln"], x)
        p = lp["mamba"]
        di, g, n, hh, hp = (cfg.ssm_d_inner, cfg.ssm_ngroups, cfg.ssm_state,
                            cfg.ssm_nheads, cfg.ssm_headdim)
        zxbcdt = h @ p["in_proj"].astype(h.dtype)
        z, xbc_x, bc, dt = _split_proj(cfg, zxbcdt)
        xbc = jnp.concatenate([xbc_x, bc], -1)
        w = p["conv_w"].astype(h.dtype)
        xp = jnp.pad(xbc, [(0, 0), (cfg.ssm_conv - 1, 0), (0, 0)])
        conv = sum(xp[:, i:i + s] * w[i] for i in range(cfg.ssm_conv))
        conv = jax.nn.silu(conv + p["conv_b"].astype(h.dtype))
        xin, B, C = jnp.split(conv, [di, di + g * n], -1)
        dtv = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"])
        A = -jnp.exp(p["A_log"])
        y, st = ssd_chunked(xin.reshape(b, s, hh, hp), dtv, A,
                            B.reshape(b, s, g, n), C.reshape(b, s, g, n),
                            chunk=cfg.ssm_chunk)
        y = y + xin.reshape(b, s, hh, hp) * p["D"][None, None, :, None]
        y = _gated_norm(y.reshape(b, s, di), z, p["norm_scale"])
        out = (y @ p["out_proj"].astype(y.dtype)).astype(x.dtype)
        # conv state = last (w-1) pre-activation channels
        conv_state = xbc.astype(jnp.float32)[:, s - (cfg.ssm_conv - 1):]
        # ssd_chunked returns (b,h,n,p); cache stores (b,h,n,p)
        return x + out, {"conv": conv_state, "ssm": st}

    x, states = jax.lax.scan(group_body, x,
                             (params["layers"], cache["layers"]))
    h = apply_norm(cfg, params["ln_f"], x[:, -1:])
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0]
    return logits, {"layers": states,
                    "length": cache["length"] + tokens.shape[1]}


def decode_step(cfg: ArchConfig, params: dict, tokens: jax.Array,
                cache: dict) -> tuple[jax.Array, dict]:
    x = embed_tokens(cfg, params["embed"], tokens[:, None])[:, 0]

    def group_body(x, xs):
        gp, st = xs
        lp = gp[0]
        h = apply_norm(cfg, lp["ln"], x[:, None])[:, 0]
        out, st2 = mamba_block_decode(cfg, lp["mamba"], h, st)
        return x + out, st2

    x, states = jax.lax.scan(group_body, x,
                             (params["layers"], cache["layers"]))
    h = apply_norm(cfg, params["ln_f"], x[:, None])
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0]
    return logits, {"layers": states, "length": cache["length"] + 1}


__all__ = ["decode_step", "forward", "init_cache", "init_params", "loss_fn",
           "prefill"]
