"""Model zoo: one pure-JAX implementation per assigned architecture family.

``get_model(cfg)`` returns a uniform ``Model`` API used by the launcher,
trainer, server and dry-run:

* ``init_params(key)``                      -> param pytree
* ``loss_fn(params, batch)``                -> scalar loss (train step core)
* ``init_cache(batch, max_len)``            -> serving cache pytree
* ``prefill(params, batch, cache)``         -> (last logits (B,V), cache)
* ``decode_step(params, tokens, cache)``    -> (logits (B,V), cache)
* ``extra_inputs(shape)``                   -> stub-frontend input specs
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from . import (attention, common, config, mamba, moe, paligemma, ssm,
               transformer, whisper, zamba)
from .config import ArchConfig


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    init_params: Callable[[Any], dict]
    loss_fn: Callable[[dict, dict], jax.Array]
    init_cache: Callable[[int, int], dict]
    prefill: Callable[[dict, dict, dict], tuple]
    decode_step: Callable[[dict, jax.Array, dict], tuple]
    # stub-frontend extra batch inputs: name -> (shape_fn(batch, seq), dtype)
    extra_inputs: dict


def get_model(cfg: ArchConfig) -> Model:
    if cfg.family in ("dense",):
        return Model(
            cfg,
            init_params=lambda key: transformer.init_params(cfg, key),
            loss_fn=lambda p, b: transformer.loss_fn(cfg, p, b),
            init_cache=lambda bs, ml: transformer.init_cache(cfg, bs, ml),
            prefill=lambda p, b, c: transformer.prefill(cfg, p, b["tokens"], c),
            decode_step=lambda p, t, c: transformer.decode_step(cfg, p, t, c),
            extra_inputs={},
        )
    if cfg.family == "moe":
        return Model(
            cfg,
            init_params=lambda key: moe.init_params(cfg, key),
            loss_fn=lambda p, b: moe.loss_fn(cfg, p, b),
            init_cache=lambda bs, ml: moe.init_cache(cfg, bs, ml),
            prefill=lambda p, b, c: moe.prefill(cfg, p, b["tokens"], c),
            decode_step=lambda p, t, c: moe.decode_step(cfg, p, t, c),
            extra_inputs={},
        )
    if cfg.family == "ssm":
        return Model(
            cfg,
            init_params=lambda key: mamba.init_params(cfg, key),
            loss_fn=lambda p, b: mamba.loss_fn(cfg, p, b),
            init_cache=lambda bs, ml: mamba.init_cache(cfg, bs, ml),
            prefill=lambda p, b, c: mamba.prefill(cfg, p, b["tokens"], c),
            decode_step=lambda p, t, c: mamba.decode_step(cfg, p, t, c),
            extra_inputs={},
        )
    if cfg.family == "hybrid":
        return Model(
            cfg,
            init_params=lambda key: zamba.init_params(cfg, key),
            loss_fn=lambda p, b: zamba.loss_fn(cfg, p, b),
            init_cache=lambda bs, ml: zamba.init_cache(cfg, bs, ml),
            prefill=lambda p, b, c: zamba.prefill(cfg, p, b["tokens"], c),
            decode_step=lambda p, t, c: zamba.decode_step(cfg, p, t, c),
            extra_inputs={},
        )
    if cfg.family == "vlm":
        return Model(
            cfg,
            init_params=lambda key: paligemma.init_params(cfg, key),
            loss_fn=lambda p, b: paligemma.loss_fn(cfg, p, b),
            init_cache=lambda bs, ml: paligemma.init_cache(cfg, bs, ml),
            prefill=lambda p, b, c: paligemma.prefill(cfg, p, b["tokens"], c,
                                                      b["patches"]),
            decode_step=lambda p, t, c: paligemma.decode_step(cfg, p, t, c),
            extra_inputs={"patches": (
                lambda bs, seq: (bs, cfg.vis_tokens, cfg.vis_dim),
                jnp.bfloat16 if cfg.compute_dtype == "bfloat16"
                else jnp.float32)},
        )
    if cfg.family == "audio":
        return Model(
            cfg,
            init_params=lambda key: whisper.init_params(cfg, key),
            loss_fn=lambda p, b: whisper.loss_fn(cfg, p, b),
            init_cache=lambda bs, ml: whisper.init_cache(cfg, bs, ml),
            prefill=lambda p, b, c: whisper.prefill(cfg, p, b["tokens"], c,
                                                    b["frames"]),
            decode_step=lambda p, t, c: whisper.decode_step(cfg, p, t, c),
            extra_inputs={"frames": (
                lambda bs, seq: (bs, cfg.enc_frames, cfg.d_model),
                jnp.bfloat16 if cfg.compute_dtype == "bfloat16"
                else jnp.float32)},
        )
    raise KeyError(f"unknown model family {cfg.family!r}")


__all__ = ["ArchConfig", "Model", "attention", "common", "config",
           "get_model", "mamba", "moe", "paligemma", "ssm", "transformer",
           "whisper", "zamba"]
