"""Shared building blocks: initializers, norms, RoPE, MLPs, embeddings.

Everything is a pure function over explicit parameter pytrees (no flax);
``init_*`` builders return nested dicts, ``apply``-style functions consume
them.  Compute happens in ``cfg.compute_dtype``; normalization statistics
and softmax always in f32.
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp

from .config import ArchConfig


def cdt(cfg: ArchConfig):
    return jnp.dtype(cfg.compute_dtype)


def pdt(cfg: ArchConfig):
    return jnp.dtype(cfg.param_dtype)


# ---------------------------------------------------------------------------
# activation sharding (sequence parallelism for the residual stream)
# ---------------------------------------------------------------------------

# configured by the launcher/dry-run (requires an ambient mesh); tests and
# single-device runs leave it unset -> no-op.
_ACT_AXES: dict = {"batch": None, "seq": None, "heads": None, "vocab": None}


def current_mesh():
    """The ambient mesh, across jax versions: ``jax.sharding
    .get_abstract_mesh`` (new) or the thread-resources physical mesh set by
    ``with mesh:`` (0.4.x).  Returns None when no mesh is active."""
    getter = getattr(jax.sharding, "get_abstract_mesh", None)
    if getter is not None:
        m = getter()
        return None if m is None or getattr(m, "empty", False) else m
    from jax._src import mesh as _mesh
    pm = _mesh.thread_resources.env.physical_mesh
    return None if pm.empty else pm


def configure_activation_sharding(batch_axes=None, seq_axes=None,
                                  heads_axes=None, vocab_axes=None) -> None:
    """E.g. batch_axes=("pod","data"), seq_axes="model", heads_axes="model".
    ``seq`` shards the residual stream (sequence parallelism); ``heads``
    forces Megatron-style head-parallel attention; ``vocab`` keeps logits
    and their gradients vocab-sharded through the loss.  All None ->
    disabled."""
    _ACT_AXES["batch"] = batch_axes
    _ACT_AXES["seq"] = seq_axes
    _ACT_AXES["heads"] = heads_axes
    _ACT_AXES["vocab"] = vocab_axes


def shard_act(x: jax.Array, logical: tuple) -> jax.Array:
    """Constrain an activation; ``logical`` entries are "batch"/"seq"/
    "heads"/None per dim.  No-op unless configure_activation_sharding was
    called inside a mesh context.  A "heads" dim not divisible by its mesh
    axis falls back to unsharded."""
    if all(v is None for v in _ACT_AXES.values()):
        return x
    from jax.sharding import PartitionSpec as P

    spec = []
    for d, l in enumerate(logical):
        ax = _ACT_AXES.get(l) if isinstance(l, str) else None
        if ax is not None:
            import numpy as _np
            mesh = current_mesh()
            if mesh is None:
                ax = None
            else:
                size = int(_np.prod([mesh.shape[a] for a in
                                     ((ax,) if isinstance(ax, str) else ax)]))
                if x.shape[d] % size != 0 or x.shape[d] < size:
                    ax = None
        spec.append(ax)
    return jax.lax.with_sharding_constraint(x, P(*spec))


# ---------------------------------------------------------------------------
# initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, dtype, in_axis: int = 0) -> jax.Array:
    fan_in = shape[in_axis] if isinstance(in_axis, int) else \
        math.prod(shape[a] for a in in_axis)
    std = 1.0 / math.sqrt(max(fan_in, 1))
    return (jax.random.normal(key, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype) -> jax.Array:
    return (jax.random.normal(key, shape, jnp.float32)).astype(dtype)


def keygen(key):
    while True:
        key, sub = jax.random.split(key)
        yield sub


# ---------------------------------------------------------------------------
# norms
# ---------------------------------------------------------------------------


def init_norm(cfg: ArchConfig, dim: int | None = None) -> dict:
    d = dim or cfg.d_model
    p = {"scale": jnp.ones((d,), pdt(cfg))}
    if cfg.norm == "layernorm":
        p["bias"] = jnp.zeros((d,), pdt(cfg))
    return p


def apply_norm(cfg: ArchConfig, p: dict, x: jax.Array,
               eps: float = 1e-6) -> jax.Array:
    xf = x.astype(jnp.float32)
    if cfg.norm == "layernorm":
        mu = jnp.mean(xf, -1, keepdims=True)
        var = jnp.var(xf, -1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps)
        out = out * p["scale"].astype(jnp.float32) + p["bias"].astype(jnp.float32)
    else:
        ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
        out = xf * jax.lax.rsqrt(ms + eps) * p["scale"].astype(jnp.float32)
    return out.astype(x.dtype)


def rms_head_norm(x: jax.Array, scale: jax.Array, eps: float = 1e-6):
    """qwen3 qk-norm: RMS over the head_dim of (..., H, S, D) tensors."""
    xf = x.astype(jnp.float32)
    ms = jnp.mean(jnp.square(xf), -1, keepdims=True)
    return (xf * jax.lax.rsqrt(ms + eps) * scale.astype(jnp.float32)
            ).astype(x.dtype)


# ---------------------------------------------------------------------------
# rotary embeddings (with partial-rotary support)
# ---------------------------------------------------------------------------


def rope_frequencies(cfg: ArchConfig, positions: jax.Array) -> tuple:
    """(sin, cos) of shape (..., rot_dim/2) for given positions."""
    rot = int(cfg.hd * cfg.rope_frac)
    rot -= rot % 2
    inv = 1.0 / (cfg.rope_theta ** (jnp.arange(0, rot, 2, jnp.float32) / rot))
    ang = positions.astype(jnp.float32)[..., None] * inv  # (..., rot/2)
    return jnp.sin(ang), jnp.cos(ang)


def apply_rope(x: jax.Array, sin: jax.Array, cos: jax.Array) -> jax.Array:
    """x: (B, H, S, D); sin/cos: (B, S, rot/2) or (S, rot/2)."""
    rot2 = sin.shape[-1]
    rot = rot2 * 2
    xr, xp = x[..., :rot], x[..., rot:]
    x1, x2 = xr[..., 0::2], xr[..., 1::2]
    if sin.ndim == 2:
        s = sin[None, None]
        c = cos[None, None]
    else:
        s = sin[:, None]
        c = cos[:, None]
    s, c = s.astype(jnp.float32), c.astype(jnp.float32)
    x1f, x2f = x1.astype(jnp.float32), x2.astype(jnp.float32)
    o1 = x1f * c - x2f * s
    o2 = x2f * c + x1f * s
    out = jnp.stack([o1, o2], axis=-1).reshape(xr.shape).astype(x.dtype)
    return jnp.concatenate([out, xp], -1) if xp.shape[-1] else out


# ---------------------------------------------------------------------------
# MLPs
# ---------------------------------------------------------------------------


def init_mlp(cfg: ArchConfig, key, d_ff: int | None = None,
             d_model: int | None = None) -> dict:
    ks = keygen(key)
    dm = d_model or cfg.d_model
    ff = d_ff or cfg.d_ff
    dtype = pdt(cfg)
    if cfg.mlp in ("swiglu", "geglu"):
        return {
            "wi": dense_init(next(ks), (dm, ff), dtype),
            "wg": dense_init(next(ks), (dm, ff), dtype),
            "wo": dense_init(next(ks), (ff, dm), dtype),
        }
    return {
        "wi": dense_init(next(ks), (dm, ff), dtype),
        "wo": dense_init(next(ks), (ff, dm), dtype),
    }


def apply_mlp(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    h = x @ p["wi"].astype(x.dtype)
    if cfg.mlp == "swiglu":
        h = jax.nn.silu(h) * (x @ p["wg"].astype(x.dtype))
    elif cfg.mlp == "geglu":
        h = jax.nn.gelu(h) * (x @ p["wg"].astype(x.dtype))
    else:
        h = jax.nn.gelu(h)
    return h @ p["wo"].astype(x.dtype)


# ---------------------------------------------------------------------------
# embeddings / logits
# ---------------------------------------------------------------------------


def init_embed(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    p = {"tokens": embed_init(next(ks), (cfg.vocab, cfg.d_model), pdt(cfg))}
    if not cfg.tie_embeddings:
        p["unembed"] = dense_init(next(ks), (cfg.d_model, cfg.vocab), pdt(cfg))
    return p


def embed_tokens(cfg: ArchConfig, p: dict, tokens: jax.Array) -> jax.Array:
    x = p["tokens"].astype(cdt(cfg))[tokens]
    if cfg.embed_scale:
        x = x * jnp.asarray(math.sqrt(cfg.d_model), cdt(cfg))
    return x


def logits_from_hidden(cfg: ArchConfig, p: dict, x: jax.Array) -> jax.Array:
    if cfg.tie_embeddings:
        w = p["tokens"].astype(cdt(cfg)).T
    else:
        w = p["unembed"].astype(cdt(cfg))
    logits = (x @ w).astype(jnp.float32)
    if cfg.logit_softcap:
        c = cfg.logit_softcap
        logits = jnp.tanh(logits / c) * c
    return shard_act(logits, ("batch",) + (None,) * (logits.ndim - 2)
                     + ("vocab",))


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  weights: jax.Array | None = None) -> jax.Array:
    """Mean next-token CE; logits (B,S,V), targets (B,S).

    Written without ``take_along_axis`` so a vocab-sharded logits tensor
    stays sharded: the picked logit is a masked sum (iota compare) and the
    normaliser a logsumexp — both partition cleanly under GSPMD."""
    lse = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = (targets[..., None] ==
              jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                       logits.ndim - 1))
    picked = jnp.sum(jnp.where(onehot, logits, 0.0), axis=-1)
    ll = picked - lse
    if weights is None:
        weights = jnp.ones_like(ll)
    return -(ll * weights).sum() / jnp.maximum(weights.sum(), 1.0)


__all__ = ["apply_mlp", "apply_norm", "apply_rope", "cdt", "cross_entropy",
           "dense_init", "embed_init", "embed_tokens", "init_embed",
           "init_mlp", "init_norm", "keygen", "logits_from_hidden", "pdt",
           "rms_head_norm", "rope_frequencies"]
