"""Whisper-style encoder-decoder (audio backbone; conv frontend STUB).

``input_specs`` provides precomputed mel-frame embeddings
(B, enc_frames, d_model) — the conv frontend is a stub per the assignment.
Encoder: bidirectional self-attention.  Decoder: causal self-attention +
cross-attention over the encoder output; decode caches both the growing
self-KV and the static cross-KV.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from . import attention as attn_mod
from .common import (apply_mlp, apply_norm, apply_rope, cdt, cross_entropy,
                     dense_init, embed_tokens, init_embed, init_mlp,
                     init_norm, keygen, logits_from_hidden, pdt,
                     rope_frequencies, shard_act)
from .config import ArchConfig
from .transformer import (_cache_write_prefill, _cache_write_token, _qkv,
                          init_attn)


def init_params(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)

    def enc_layer(k):
        kk = keygen(k)
        return [{"ln1": init_norm(cfg), "attn": init_attn(cfg, next(kk)),
                 "ln2": init_norm(cfg), "mlp": init_mlp(cfg, next(kk))}]

    def dec_layer(k):
        kk = keygen(k)
        return [{"ln1": init_norm(cfg), "attn": init_attn(cfg, next(kk)),
                 "lnx": init_norm(cfg), "xattn": init_attn(cfg, next(kk)),
                 "ln2": init_norm(cfg), "mlp": init_mlp(cfg, next(kk))}]

    enc = jax.vmap(enc_layer)(jax.random.split(next(ks), cfg.enc_layers))
    dec = jax.vmap(dec_layer)(jax.random.split(next(ks), cfg.n_layers))
    return {
        "embed": init_embed(cfg, next(ks)),
        "pos_enc": dense_init(next(ks), (cfg.enc_frames, cfg.d_model),
                              pdt(cfg)),
        "enc_layers": enc,
        "enc_ln_f": init_norm(cfg),
        "dec_layers": dec,
        "ln_f": init_norm(cfg),
    }


def encode(cfg: ArchConfig, params: dict, frames: jax.Array) -> jax.Array:
    """frames: (B, enc_frames, d_model) stub embeddings -> encoder states."""
    x = frames.astype(cdt(cfg)) + params["pos_enc"].astype(cdt(cfg))[None]
    s = x.shape[1]
    positions = jnp.arange(s)

    def body(x, gp):
        lp = gp[0]
        x = shard_act(x, ("batch", "seq", None))
        h = apply_norm(cfg, lp["ln1"], x)
        q, k, v = _qkv(cfg, lp["attn"], h)
        fn = attn_mod.select_attention(cfg, s)
        o = fn(q, k, v, causal=False)   # bidirectional
        b = x.shape[0]
        o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
        x = x + o @ lp["attn"]["wo"].astype(x.dtype)
        h = apply_norm(cfg, lp["ln2"], x)
        return x + apply_mlp(cfg, lp["mlp"], h), None

    fn_body = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(lambda c, p: fn_body(c, p), x, params["enc_layers"])
    return apply_norm(cfg, params["enc_ln_f"], x)


def _cross_attend(cfg, lp, x, enc_k, enc_v):
    b, s, _ = x.shape
    h = apply_norm(cfg, lp["lnx"], x)
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (h @ lp["xattn"]["wq"].astype(h.dtype)).reshape(b, s, hq, hd
                                                        ).transpose(0, 2, 1, 3)
    fn = attn_mod.select_attention(cfg, s)
    o = fn(q, enc_k, enc_v, causal=False)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, hq * hd)
    return o @ lp["xattn"]["wo"].astype(h.dtype)


def _enc_kv(cfg, lp, enc):
    b, se, _ = enc.shape
    hkv, hd = cfg.n_kv_heads, cfg.hd
    k = (enc @ lp["xattn"]["wk"].astype(enc.dtype)).reshape(
        b, se, hkv, hd).transpose(0, 2, 1, 3)
    v = (enc @ lp["xattn"]["wv"].astype(enc.dtype)).reshape(
        b, se, hkv, hd).transpose(0, 2, 1, 3)
    return k, v


def decode_train(cfg: ArchConfig, params: dict, tokens: jax.Array,
                 enc: jax.Array) -> jax.Array:
    x = embed_tokens(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.arange(s)

    def body(x, gp):
        lp = gp[0]
        x = shard_act(x, ("batch", "seq", None))
        h = apply_norm(cfg, lp["ln1"], x)
        q, k, v = _qkv(cfg, lp["attn"], h)
        sin, cos = rope_frequencies(cfg, positions)
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
        fn = attn_mod.select_attention(cfg, s)
        o = fn(q, k, v, causal=True)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
        x = x + o @ lp["attn"]["wo"].astype(x.dtype)
        ek, ev = _enc_kv(cfg, lp, enc)
        x = x + _cross_attend(cfg, lp, x, ek, ev)
        h = apply_norm(cfg, lp["ln2"], x)
        return x + apply_mlp(cfg, lp["mlp"], h), None

    fn_body = jax.checkpoint(body, prevent_cse=False) if cfg.remat else body
    x, _ = jax.lax.scan(lambda c, p: fn_body(c, p), x, params["dec_layers"])
    return apply_norm(cfg, params["ln_f"], x)


def loss_fn(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    enc = encode(cfg, params, batch["frames"])
    h = decode_train(cfg, params, batch["tokens"], enc)
    logits = logits_from_hidden(cfg, params["embed"], h)
    return cross_entropy(logits, batch["targets"], batch.get("weights"))


# -- serving -----------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int, dtype=None) -> dict:
    dtype = dtype or cdt(cfg)
    hkv, hd = cfg.n_kv_heads, cfg.hd
    L = cfg.n_layers
    return {
        "self": {
            "k": jnp.zeros((L, batch, hkv, max_len, hd), dtype),
            "v": jnp.zeros((L, batch, hkv, max_len, hd), dtype),
        },
        "cross": {
            "k": jnp.zeros((L, batch, hkv, cfg.enc_frames, hd), dtype),
            "v": jnp.zeros((L, batch, hkv, cfg.enc_frames, hd), dtype),
        },
        "length": jnp.zeros((batch,), jnp.int32),
    }


def prefill(cfg: ArchConfig, params: dict, tokens: jax.Array, cache: dict,
            frames: jax.Array) -> tuple[jax.Array, dict]:
    """Encode audio, precompute cross-KV, run the decoder prompt."""
    enc = encode(cfg, params, frames)
    x = embed_tokens(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.arange(s)

    def body(x, xs):
        gp, kv_self = xs
        lp = gp[0]
        h = apply_norm(cfg, lp["ln1"], x)
        q, k, v = _qkv(cfg, lp["attn"], h)
        sin, cos = rope_frequencies(cfg, positions)
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
        fn = attn_mod.select_attention(cfg, s)
        o = fn(q, k, v, causal=True)
        o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
        x = x + o @ lp["attn"]["wo"].astype(x.dtype)
        ek, ev = _enc_kv(cfg, lp, enc)
        x = x + _cross_attend(cfg, lp, x, ek, ev)
        h = apply_norm(cfg, lp["ln2"], x)
        x = x + apply_mlp(cfg, lp["mlp"], h)
        nkv = {"k": _cache_write_prefill(kv_self["k"], k, s),
               "v": _cache_write_prefill(kv_self["v"], v, s)}
        return x, (nkv, {"k": ek.astype(kv_self["k"].dtype),
                         "v": ev.astype(kv_self["v"].dtype)})

    x, (self_new, cross_new) = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self"]))
    h = apply_norm(cfg, params["ln_f"], x[:, -1:])
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0]
    return logits, {"self": self_new, "cross": cross_new,
                    "length": cache["length"] + s}


def decode_step(cfg: ArchConfig, params: dict, tokens: jax.Array,
                cache: dict) -> tuple[jax.Array, dict]:
    x = embed_tokens(cfg, params["embed"], tokens[:, None])
    length = cache["length"]
    b = tokens.shape[0]

    def body(x, xs):
        gp, kv_self, kv_cross = xs
        lp = gp[0]
        h = apply_norm(cfg, lp["ln1"], x)
        q, k, v = _qkv(cfg, lp["attn"], h)
        sin, cos = rope_frequencies(cfg, length[:, None])
        q, k = apply_rope(q, sin, cos), apply_rope(k, sin, cos)
        ck = _cache_write_token(kv_self["k"], k[:, :, 0], length)
        cv = _cache_write_token(kv_self["v"], v[:, :, 0], length)
        o = attn_mod.decode_attention(q[:, :, 0], ck, cv, length + 1)
        x = x + o.reshape(b, 1, cfg.n_heads * cfg.hd) @ \
            lp["attn"]["wo"].astype(x.dtype)
        # cross attention vs static KV
        hx = apply_norm(cfg, lp["lnx"], x)
        hq, hd = cfg.n_heads, cfg.hd
        qx = (hx @ lp["xattn"]["wq"].astype(hx.dtype)).reshape(
            b, 1, hq, hd).transpose(0, 2, 1, 3)
        se = kv_cross["k"].shape[2]
        ox = attn_mod.decode_attention(
            qx[:, :, 0], kv_cross["k"], kv_cross["v"],
            jnp.full((b,), se, jnp.int32))
        x = x + ox.reshape(b, 1, hq * hd) @ lp["xattn"]["wo"].astype(x.dtype)
        h2 = apply_norm(cfg, lp["ln2"], x)
        x = x + apply_mlp(cfg, lp["mlp"], h2)
        return x, {"k": ck, "v": cv}

    x, self_new = jax.lax.scan(
        body, x, (params["dec_layers"], cache["self"], cache["cross"]))
    h = apply_norm(cfg, params["ln_f"], x)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0]
    return logits, {"self": self_new, "cross": cache["cross"],
                    "length": length + 1}


__all__ = ["decode_step", "encode", "init_cache", "init_params", "loss_fn",
           "prefill"]
