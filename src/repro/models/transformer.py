"""Dense decoder-only transformer LM (grouped layer scan + remat).

Covers command-r-plus (parallel block, GQA), gemma3 (5:1 local:global
sliding-window pattern, geglu, logit softcap), stablelm (layernorm, partial
rope), qwen3 (qk-norm) and serves as the PaliGemma text decoder.

Layers are stacked per *group* (the local:global pattern unit) and executed
with ``lax.scan`` so the compiled HLO is one group body — essential for the
512-device dry-run of 64-layer models.  ``jax.checkpoint`` wraps the group
body when ``cfg.remat``.
"""
from __future__ import annotations

import dataclasses
import functools

import jax
import jax.numpy as jnp

from . import attention as attn
from .common import (apply_mlp, apply_norm, apply_rope, cdt, cross_entropy,
                     dense_init, embed_tokens, init_embed, init_mlp,
                     init_norm, keygen, logits_from_hidden, pdt,
                     rms_head_norm, rope_frequencies, shard_act)
from .config import ArchConfig

# ---------------------------------------------------------------------------
# layer pattern helpers
# ---------------------------------------------------------------------------


def layer_pattern(cfg: ArchConfig) -> list[bool]:
    """Per-position-in-group flag: True = sliding-window (local) layer."""
    local, glob = cfg.local_global
    if local + glob == 0:
        return [cfg.window > 0]  # uniform window (or full) single layer
    return [True] * local + [False] * glob


# ---------------------------------------------------------------------------
# parameters
# ---------------------------------------------------------------------------


def init_attn(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    d, hq, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv_heads, cfg.hd
    dtype = pdt(cfg)
    p = {
        "wq": dense_init(next(ks), (d, hq * hd), dtype),
        "wk": dense_init(next(ks), (d, hkv * hd), dtype),
        "wv": dense_init(next(ks), (d, hkv * hd), dtype),
        "wo": dense_init(next(ks), (hq * hd, d), dtype),
    }
    if cfg.qk_norm:
        p["q_norm"] = jnp.ones((hd,), dtype)
        p["k_norm"] = jnp.ones((hd,), dtype)
    return p


def init_layer(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    p = {
        "ln1": init_norm(cfg),
        "attn": init_attn(cfg, next(ks)),
        "mlp": init_mlp(cfg, next(ks)),
    }
    if not cfg.parallel_block:
        p["ln2"] = init_norm(cfg)
    return p


def init_params(cfg: ArchConfig, key) -> dict:
    ks = keygen(key)
    n_groups, per = cfg.layer_groups()

    def group(k):
        gks = jax.random.split(k, per)
        return [init_layer(cfg, gk) for gk in gks]

    layers = jax.vmap(group)(jax.random.split(next(ks), n_groups))
    return {
        "embed": init_embed(cfg, next(ks)),
        "layers": layers,  # list of per trees, each leaf (n_groups, ...)
        "ln_f": init_norm(cfg),
    }


# ---------------------------------------------------------------------------
# attention projection / core
# ---------------------------------------------------------------------------


def _qkv(cfg: ArchConfig, p: dict, x: jax.Array):
    b, s, d = x.shape
    hq, hkv, hd = cfg.n_heads, cfg.n_kv_heads, cfg.hd
    q = (x @ p["wq"].astype(x.dtype)).reshape(b, s, hq, hd).transpose(0, 2, 1, 3)
    k = (x @ p["wk"].astype(x.dtype)).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    v = (x @ p["wv"].astype(x.dtype)).reshape(b, s, hkv, hd).transpose(0, 2, 1, 3)
    if cfg.qk_norm:
        q = rms_head_norm(q, p["q_norm"])
        k = rms_head_norm(k, p["k_norm"])
    return q, k, v


def attention_block(cfg: ArchConfig, p: dict, x: jax.Array, *, local: bool,
                    positions: jax.Array) -> jax.Array:
    """Full-sequence self attention (train / prefill compute).
    ``p`` is the attention subtree (wq/wk/wv/wo [+ q_norm/k_norm])."""
    b, s, d = x.shape
    q, k, v = _qkv(cfg, p, x)
    if cfg.rope_frac > 0:
        sin, cos = rope_frequencies(cfg, positions)
        q = apply_rope(q, sin, cos)
        k = apply_rope(k, sin, cos)
    window = cfg.window if local else 0
    if window and s > window:
        o = attn.sliding_attention(q, k, v, window=window,
                                   block_q=min(cfg.attn_block_q, s))
    else:
        fn = attn.select_attention(cfg, s)
        o = fn(q, k, v, causal=True, window=window)
    o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
    return o @ p["wo"].astype(x.dtype)


def layer_apply(cfg: ArchConfig, p: dict, x: jax.Array, *, local: bool,
                positions: jax.Array) -> jax.Array:
    h = apply_norm(cfg, p["ln1"], x)
    a = attention_block(cfg, p["attn"], h, local=local, positions=positions)
    if cfg.parallel_block:  # command-r: attn + mlp from the same norm
        m = apply_mlp(cfg, p["mlp"], h)
        return x + a + m
    x = x + a
    h = apply_norm(cfg, p["ln2"], x)
    return x + apply_mlp(cfg, p["mlp"], h)


# ---------------------------------------------------------------------------
# forward (train) — grouped scan
# ---------------------------------------------------------------------------


def forward(cfg: ArchConfig, params: dict, tokens: jax.Array,
            embeds: jax.Array | None = None) -> jax.Array:
    """Returns final hidden states (B,S,D).  ``embeds`` overrides token
    embedding (PaliGemma prefixes image embeddings)."""
    x = embeds if embeds is not None else \
        embed_tokens(cfg, params["embed"], tokens)
    x = shard_act(x, ("batch", "seq", None))  # boundary: embed -> scan
    b, s, _ = x.shape
    positions = jnp.arange(s)
    pattern = layer_pattern(cfg)

    def group_body(x, group_params):
        x = shard_act(x, ("batch", "seq", None))
        for j, local in enumerate(pattern):
            x = layer_apply(cfg, group_params[j], x,
                            local=local, positions=positions)
        return x, None

    body = group_body
    if cfg.remat:
        body = jax.checkpoint(group_body, prevent_cse=False)
    x, _ = jax.lax.scan(lambda c, p: body(c, p), x, params["layers"])
    x = shard_act(x, ("batch", "seq", None))  # boundary: scan -> loss
    return apply_norm(cfg, params["ln_f"], x)


def loss_fn(cfg: ArchConfig, params: dict, batch: dict) -> jax.Array:
    h = forward(cfg, params, batch["tokens"],
                embeds=batch.get("embeds"))
    logits = logits_from_hidden(cfg, params["embed"], h)
    return cross_entropy(logits, batch["targets"], batch.get("weights"))


# ---------------------------------------------------------------------------
# KV cache + serving
# ---------------------------------------------------------------------------


def init_cache(cfg: ArchConfig, batch: int, max_len: int,
               dtype=None) -> dict:
    """Local (window) layers get window-sized rolling caches; global layers
    full ``max_len`` — the memory structure that makes long_500k viable."""
    dtype = dtype or cdt(cfg)
    n_groups, per = cfg.layer_groups()
    pattern = layer_pattern(cfg)
    hkv, hd = cfg.n_kv_heads, cfg.hd
    caches = []
    for local in pattern:
        slen = min(cfg.window, max_len) if (local and cfg.window) else max_len
        caches.append({
            "k": jnp.zeros((n_groups, batch, hkv, slen, hd), dtype),
            "v": jnp.zeros((n_groups, batch, hkv, slen, hd), dtype),
        })
    return {"layers": caches, "length": jnp.zeros((batch,), jnp.int32)}


def _cache_write_prefill(cache_k, k, length):
    """Write a full prefill (B,Hkv,S,D) into the cache.  Rolling caches
    (w < s) keep the last w tokens at their canonical slots ``t % w`` so
    decode's rolling writes overwrite the oldest entry."""
    w = cache_k.shape[2]
    s = k.shape[2]
    if s >= w:
        last = k[:, :, s - w:].astype(cache_k.dtype)
        return jnp.roll(last, s % w, axis=2)
    return jax.lax.dynamic_update_slice_in_dim(
        cache_k, k.astype(cache_k.dtype), 0, axis=2)


def prefill(cfg: ArchConfig, params: dict, tokens: jax.Array,
            cache: dict, embeds: jax.Array | None = None
            ) -> tuple[jax.Array, dict]:
    """Process the prompt; returns (last-token logits (B,V), filled cache)."""
    x = embeds if embeds is not None else \
        embed_tokens(cfg, params["embed"], tokens)
    b, s, _ = x.shape
    positions = jnp.arange(s)
    pattern = layer_pattern(cfg)

    def group_body(x, xs):
        group_params, kv_in = xs
        kv_out = []
        x = shard_act(x, ("batch", "seq", None))
        for j, local in enumerate(pattern):
            lp = group_params[j]
            h = apply_norm(cfg, lp["ln1"], x)
            q, k, v = _qkv(cfg, lp["attn"], h)
            if cfg.rope_frac > 0:
                sin, cos = rope_frequencies(cfg, positions)
                q = apply_rope(q, sin, cos)
                k = apply_rope(k, sin, cos)
            window = cfg.window if local else 0
            if window and s > window:
                o = attn.sliding_attention(q, k, v, window=window,
                                           block_q=min(cfg.attn_block_q, s))
            else:
                fn = attn.select_attention(cfg, s)
                o = fn(q, k, v, causal=True, window=window)
            o = o.transpose(0, 2, 1, 3).reshape(b, s, cfg.n_heads * cfg.hd)
            a = o @ lp["attn"]["wo"].astype(x.dtype)
            kv_out.append({
                "k": _cache_write_prefill(kv_in[j]["k"], k, s),
                "v": _cache_write_prefill(kv_in[j]["v"], v, s),
            })
            if cfg.parallel_block:
                x = x + a + apply_mlp(cfg, lp["mlp"], h)
            else:
                x = x + a
                h2 = apply_norm(cfg, lp["ln2"], x)
                x = x + apply_mlp(cfg, lp["mlp"], h2)
        return x, kv_out

    # scan over groups, threading per-group cache slices
    kv_by_layer = cache["layers"]
    x, kv_new = jax.lax.scan(group_body, x, (params["layers"], kv_by_layer))
    h = apply_norm(cfg, params["ln_f"], x[:, -1:])
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0]
    new_cache = {"layers": kv_new, "length": cache["length"] + s}
    return logits, new_cache


def _scatter_write(cache_k, k_new, pos):
    b, hkv, w, hd = cache_k.shape
    bi = jnp.arange(b)[:, None]
    hi = jnp.arange(hkv)[None, :]
    return cache_k.at[bi, hi, pos[:, None]].set(k_new.astype(cache_k.dtype))


def _cache_write_token(cache_k, k_new, length):
    """Write one token (B,Hkv,D) at per-batch rolling positions.

    When the cache's sequence dim is sharded over the ``model`` axis (the
    launch convention for kv_heads < |model|), a naive scatter makes GSPMD
    replicate the whole cache per step (an all-gather of GBs per layer per
    token).  In that regime we drop to a shard_map: every seq shard tests
    whether each row's position lands in its slice and updates locally —
    zero collective bytes."""
    from jax.sharding import PartitionSpec as P

    from .common import _ACT_AXES

    b, hkv, w, hd = cache_k.shape
    pos = length % w
    seq_ax = _ACT_AXES.get("seq")
    if not seq_ax:
        return _scatter_write(cache_k, k_new, pos)
    from .common import current_mesh
    mesh = current_mesh()
    if mesh is None or seq_ax not in getattr(mesh, "shape", {}):
        return _scatter_write(cache_k, k_new, pos)
    n = mesh.shape[seq_ax]
    if hkv % n == 0 or w % n != 0 or w < n:
        # launch convention shards kv-heads instead -> scatter is local
        return _scatter_write(cache_k, k_new, pos)
    batch_ax = _ACT_AXES.get("batch")
    baxes = batch_ax if (batch_ax and b % _axes_size(mesh, batch_ax) == 0) \
        else None

    def body(ck, kn, p):
        idx = jax.lax.axis_index(seq_ax)
        s_local = ck.shape[2]
        local = p - idx * s_local
        in_range = (local >= 0) & (local < s_local)
        safe = jnp.clip(local, 0, s_local - 1)
        bl, hl = ck.shape[0], ck.shape[1]
        bi = jnp.arange(bl)[:, None]
        hi = jnp.arange(hl)[None, :]
        old = ck[bi, hi, safe[:, None]]
        upd = jnp.where(in_range[:, None, None], kn.astype(ck.dtype), old)
        return ck.at[bi, hi, safe[:, None]].set(upd)

    cache_spec = P(baxes, None, seq_ax, None)
    return jax.shard_map(
        body, mesh=mesh,
        in_specs=(cache_spec, P(baxes, None, None), P(baxes)),
        out_specs=cache_spec,
    )(cache_k, k_new.astype(cache_k.dtype), pos)


def _axes_size(mesh, axes) -> int:
    import numpy as _np
    return int(_np.prod([mesh.shape[a] for a in
                         ((axes,) if isinstance(axes, str) else axes)]))


def decode_step(cfg: ArchConfig, params: dict, tokens: jax.Array,
                cache: dict) -> tuple[jax.Array, dict]:
    """One token for every sequence.  tokens: (B,) int32."""
    b = tokens.shape[0]
    x = embed_tokens(cfg, params["embed"], tokens[:, None])  # (B,1,D)
    length = cache["length"]  # (B,)
    pattern = layer_pattern(cfg)

    def group_body(x, xs):
        group_params, kv_in = xs
        kv_out = []
        for j, local in enumerate(pattern):
            lp = group_params[j]
            h = apply_norm(cfg, lp["ln1"], x)
            q, k, v = _qkv(cfg, lp["attn"], h)       # (B,H,1,D)
            if cfg.rope_frac > 0:
                sin, cos = rope_frequencies(cfg, length[:, None])
                q = apply_rope(q, sin, cos)
                k = apply_rope(k, sin, cos)
            ck = _cache_write_token(kv_in[j]["k"], k[:, :, 0], length)
            cv = _cache_write_token(kv_in[j]["v"], v[:, :, 0], length)
            kv_out.append({"k": ck, "v": cv})
            w = ck.shape[2]
            valid = jnp.minimum(length + 1, w)
            o = attn.decode_attention(q[:, :, 0], ck, cv, valid)
            a = o.reshape(b, 1, cfg.n_heads * cfg.hd) @ \
                lp["attn"]["wo"].astype(x.dtype)
            if cfg.parallel_block:
                x = x + a + apply_mlp(cfg, lp["mlp"], h)
            else:
                x = x + a
                h2 = apply_norm(cfg, lp["ln2"], x)
                x = x + apply_mlp(cfg, lp["mlp"], h2)
        return x, kv_out

    x, kv_new = jax.lax.scan(group_body, x, (params["layers"],
                                             cache["layers"]))
    h = apply_norm(cfg, params["ln_f"], x)
    logits = logits_from_hidden(cfg, params["embed"], h)[:, 0]
    return logits, {"layers": kv_new, "length": length + 1}


__all__ = ["decode_step", "forward", "init_cache", "init_params",
           "layer_pattern", "loss_fn", "prefill"]
