"""Mamba2 SSD (state-space duality) chunked scan — Pallas kernel.

The SSD dual form splits the sequence into chunks of length L:

* intra-chunk (quadratic, MXU-bound):  Y_intra = (C B^T ⊙ Γ) X
* chunk states (GEMM):                 S_c     = (B ⊙ γ_end)^T X
* inter-chunk (tiny recurrence):       H_c     = exp(ΔA_c) H_{c-1} + S_c
* state -> output (GEMM):              Y_inter = γ_start ⊙ (C H_{c-1})

The Pallas kernel fuses the two FLOPs-dominant chunk-local stages (Y_intra
and S_c) per (batch·head, chunk) grid cell — a direct port of the paper's
multi-compute-node schedule (MXU for the GEMMs, VPU for the decay masks)
onto one VMEM-resident block.  The O(chunks) recurrence and the Y_inter
GEMM run as jnp ops (they are <2% of FLOPs at L=256).

Shapes (head-batched): x (BH, S, P), dt (BH, S), B,C (BH, S, N), A (BH,).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams


def _ssd_chunk_kernel(x_ref, dt_ref, b_ref, c_ref, a_ref,
                      y_ref, state_ref, dsum_ref):
    """One (bh, chunk) cell: intra-chunk output + end-of-chunk state."""
    x = x_ref[0].astype(jnp.float32)      # (L, P)
    dt = dt_ref[0].astype(jnp.float32)    # (L, 1) — lane-padded
    bmat = b_ref[0].astype(jnp.float32)   # (L, N)
    cmat = c_ref[0].astype(jnp.float32)   # (L, N)
    a = a_ref[0]                          # scalar decay rate (f32, SMEM)

    da = dt[:, 0] * a                     # (L,) log-decay increments
    cum = jnp.cumsum(da)                  # inclusive cumsum
    L = x.shape[0]
    # Γ[i,j] = exp(cum_i - cum_j) for j <= i (segment decay), else 0.
    # Mask inside the exp so the masked branch cannot overflow.
    seg = cum[:, None] - cum[None, :]
    ii = jax.lax.broadcasted_iota(jnp.int32, (L, L), 0)
    jj = jax.lax.broadcasted_iota(jnp.int32, (L, L), 1)
    gamma = jnp.exp(jnp.where(jj <= ii, seg, -1e30))

    # Y_intra = ((C B^T) ⊙ Γ) (Δ ⊙ X)
    att = jnp.dot(cmat, bmat.T, preferred_element_type=jnp.float32) * gamma
    xdt = x * dt[:, :1]
    y_ref[0] = jnp.dot(att, xdt, preferred_element_type=jnp.float32
                       ).astype(y_ref.dtype)

    # S_c = (B ⊙ exp(cum_L - cum))^T (Δ ⊙ X)   -> (N, P)
    decay_to_end = jnp.exp(cum[-1] - cum)[:, None]
    state_ref[0] = jnp.dot((bmat * decay_to_end).T, xdt,
                           preferred_element_type=jnp.float32)
    dsum_ref[0, 0] = cum[-1]


@functools.partial(jax.jit, static_argnames=("chunk", "interpret"))
def ssd_chunk_scan(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                   C: jax.Array, *, chunk: int = 64,
                   init_state: jax.Array | None = None,
                   interpret: bool = False) -> tuple[jax.Array, jax.Array]:
    """Head-batched SSD: x (BH,S,P), dt (BH,S), A (BH,), B/C (BH,S,N).

    Returns (y (BH,S,P), final_state (BH,N,P)).  S % chunk == 0 (ops.py
    pads).  The chunk-local heavy stages run in the Pallas kernel; the
    cross-chunk combination is jnp.
    """
    bh, s, p = x.shape
    n = B.shape[-1]
    assert s % chunk == 0, (s, chunk)
    nck = s // chunk
    dt2 = dt[..., None]  # (BH,S,1) lane dim for VMEM tiling

    y_intra, states, dsums = pl.pallas_call(
        _ssd_chunk_kernel,
        grid=(bh, nck),
        in_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, 1), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, chunk, n), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1,), lambda b, c: (b,), memory_space=pltpu.SMEM),
        ],
        out_specs=[
            pl.BlockSpec((1, chunk, p), lambda b, c: (b, c, 0)),
            pl.BlockSpec((1, n, p), lambda b, c: (b * nck + c, 0, 0)),
            pl.BlockSpec((1, 1), lambda b, c: (b * nck + c, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s, p), jnp.float32),
            jax.ShapeDtypeStruct((bh * nck, n, p), jnp.float32),
            jax.ShapeDtypeStruct((bh * nck, 1), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel")),
        interpret=interpret,
    )(x, dt2, B, C, A.astype(jnp.float32))

    states = states.reshape(bh, nck, n, p)
    dsums = dsums.reshape(bh, nck)

    # inter-chunk recurrence over ncache states: H_c = e^{dsum_c} H_{c-1} + S_c
    def comb(left, right):
        dl, sl = left
        dr, sr = right
        return dl + dr, sr + sl * jnp.exp(dr)[..., None, None]

    dcum, hstates = jax.lax.associative_scan(
        comb, (dsums.swapaxes(0, 1), states.swapaxes(0, 1)))
    hstates = hstates.swapaxes(0, 1)  # (BH, ncache, N, P) — end-of-chunk states
    if init_state is not None:
        carry = jnp.exp(dcum.swapaxes(0, 1))[..., None, None] * \
            init_state[:, None].astype(jnp.float32)
        hstates = hstates + carry
    # states entering each chunk: shift right
    h_prev = jnp.concatenate([
        (init_state[:, None].astype(jnp.float32) if init_state is not None
         else jnp.zeros_like(hstates[:, :1])),
        hstates[:, :-1]], axis=1)  # (BH, ncache, N, P)

    # Y_inter[t] = exp(cum_t) * C_t @ H_prev(chunk(t))
    dtf = dt.astype(jnp.float32).reshape(bh, nck, chunk)
    cum_in = jnp.cumsum(dtf * A.astype(jnp.float32)[:, None, None], axis=-1)
    gamma_start = jnp.exp(cum_in)  # (BH,ncache,L)
    Cc = C.astype(jnp.float32).reshape(bh, nck, chunk, n)
    y_inter = jnp.einsum("bcln,bcnp->bclp", Cc, h_prev) * \
        gamma_start[..., None]
    y = y_intra + y_inter.reshape(bh, s, p)
    return y.astype(x.dtype), hstates[:, -1]


__all__ = ["ssd_chunk_scan"]
