"""Covenant -> Pallas bridge: the paper's Algorithm-1 tiler selects the
BlockSpec geometry for our TPU kernels (DESIGN.md §3, deviation D1).

The TPU-v5e ACG models VMEM capacity and the MXU's (128,128,128) GEMM
capability.  ``gemm_blocks`` runs the Covenant pipeline (placement, compute
mapping, Algorithm-1 tiling enumeration + cost-based selection) on a GEMM
codelet of the requested problem size and returns the chosen tile as Pallas
block sizes.  The paper's alignment rule — "data chunks are divisible by the
size of an addressable element" (§2.1.1) — becomes the (8,128) / MXU-128
alignment filter applied to the candidate set.
"""
from __future__ import annotations

import functools
import math

from repro.core import library, scheduler, targets
from repro.core.scheduler import enumerate_tilings, plan_operands

# MXU systolic dims / VPU lane layout on TPU v5e
MXU = 128
SUBLANE = 8


def _align_score(t: dict[str, int], dims: dict[str, int]) -> tuple:
    """Prefer MXU-aligned tiles (multiples of 128 on m/n/k, 8 on m)."""
    def sc(var, unit):
        v = t.get(var, 1)
        full = dims[var]
        if v % unit == 0 or v == full:
            return 0
        return 1
    return (sc("n", MXU) + sc("k", MXU) + sc("m", SUBLANE),)


@functools.lru_cache(maxsize=512)
def gemm_blocks(m: int, n: int, k: int, in_dtype: str = "bf16",
                acc_dtype: str = "f32",
                vmem_budget_frac: float = 1.0) -> tuple[int, int, int]:
    """(block_m, block_n, block_k) for an (m,n,k) GEMM, chosen by the
    Covenant tiler against the TPU-v5e ACG."""
    acg = targets.tpu_v5e_acg()
    cdlt = library.gemm(m, n, k, in_dtype=in_dtype, acc_dtype=acc_dtype,
                        name=f"tpugemm_{m}x{n}x{k}")
    scheduler.place_operands(cdlt, acg)
    scheduler.map_compute(cdlt, acg, vectorize=True)
    plans = plan_operands(cdlt, acg)
    cands = enumerate_tilings(cdlt, acg, plans, max_candidates=6000)
    if not cands:
        cands = enumerate_tilings(cdlt, acg, plans, max_candidates=6000,
                                  pad_align=True)
    dims = {"m": m, "n": n, "k": k}
    best, best_key = None, None
    for t in cands:
        cost = scheduler.estimate_tiling_cost(cdlt, acg, plans, t)
        key = (_align_score(t, dims), cost)
        if best_key is None or key < best_key:
            best, best_key = t, key
    assert best is not None, f"no tiling for GEMM {m}x{n}x{k}"
    bm, bn, bk = best.get("m", m), best.get("n", n), best.get("k", k)
    # clamp to hardware-friendly minima (grid blocks must tile the padded
    # problem; ops.py pads to these multiples)
    bm = max(SUBLANE, min(bm, m if m % SUBLANE == 0 else _round_up(m, SUBLANE)))
    bn = min(_round_up(bn, MXU), _round_up(n, MXU))
    bk = min(_round_up(bk, MXU), _round_up(k, MXU))
    return bm, bn, bk


def _round_up(x: int, unit: int) -> int:
    return max(unit, math.ceil(x / unit) * unit)


def attention_blocks(seq_q: int, seq_k: int, head_dim: int,
                     ) -> tuple[int, int]:
    """(block_q, block_kv) for flash attention: the Covenant tiler sizes the
    q/k tiles via the equivalent QK^T GEMM (m=seq_q, n=seq_k, k=head_dim)."""
    bm, bn, _ = gemm_blocks(seq_q, seq_k, max(head_dim, MXU))
    bq = min(_round_up(bm, MXU), _round_up(seq_q, MXU)) if seq_q >= MXU \
        else _round_up(seq_q, SUBLANE)
    bkv = min(_round_up(bn, MXU), _round_up(seq_k, MXU))
    # keep combined working set within a conservative VMEM slice: the flash
    # inner block materialises (bq, bkv) logits + (bq, d) accumulators
    bq = min(bq, 4 * MXU)
    while bq * bkv > 256 * 1024 and bkv > MXU:
        bkv //= 2
    return bq, bkv


__all__ = ["MXU", "SUBLANE", "attention_blocks", "gemm_blocks"]
