"""Blocked GEMM Pallas kernel for TPU.

Grid (m, n, k) with a VMEM accumulator scratch; block geometry comes from
the Covenant tiler (``tiling.gemm_blocks``) so the paper's Algorithm-1
machinery literally chooses the ``BlockSpec``s.  Supports bf16/f32 -> f32
and s8 -> s32 (the paper's INT8-in / INT32-out regime, D3).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams


def _matmul_kernel(a_ref, b_ref, o_ref, acc_ref):
    k = pl.program_id(2)

    @pl.when(k == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jnp.dot(a_ref[...], b_ref[...],
                            preferred_element_type=acc_ref.dtype)

    @pl.when(k == pl.num_programs(2) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("block_m", "block_n", "block_k",
                                             "out_dtype", "interpret"))
def matmul(a: jax.Array, b: jax.Array, *, block_m: int, block_n: int,
           block_k: int, out_dtype=jnp.float32,
           interpret: bool = False) -> jax.Array:
    """C[M,N] = A[M,K] @ B[K,N].  Dims must be divisible by the block sizes
    (ops.py pads); accumulation is f32 for float inputs, i32 for int8."""
    m, k = a.shape
    k2, n = b.shape
    assert k == k2, (a.shape, b.shape)
    assert m % block_m == 0 and n % block_n == 0 and k % block_k == 0, (
        (m, n, k), (block_m, block_n, block_k))
    acc_dtype = jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32
    return pl.pallas_call(
        _matmul_kernel,
        grid=(m // block_m, n // block_n, k // block_k),
        in_specs=[
            pl.BlockSpec((block_m, block_k), lambda i, j, kk: (i, kk)),
            pl.BlockSpec((block_k, block_n), lambda i, j, kk: (kk, j)),
        ],
        out_specs=pl.BlockSpec((block_m, block_n), lambda i, j, kk: (i, j)),
        out_shape=jax.ShapeDtypeStruct((m, n), out_dtype),
        scratch_shapes=[pltpu.VMEM((block_m, block_n), acc_dtype)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(a, b)


__all__ = ["matmul"]
