"""Pallas TPU kernels scheduled by the Covenant tiler (DESIGN.md §3).

``ops`` is the public API (padding + Covenant BlockSpecs + CPU interpret
fallback); ``ref`` holds the pure-jnp oracles every kernel is tested
against; ``tiling`` is the Algorithm-1 -> BlockSpec bridge.
"""
from . import flash_attention, matmul, ops, ref, ssd_scan, tiling
from .ops import (covenant_attention, covenant_decode_attention,
                  covenant_matmul, covenant_ssd)

__all__ = ["covenant_attention", "covenant_decode_attention",
           "covenant_matmul", "covenant_ssd", "flash_attention", "matmul",
           "ops", "ref", "ssd_scan", "tiling"]
