"""Version-compat shims for jax.experimental.pallas on TPU.

jax renamed ``pltpu.TPUCompilerParams`` to ``pltpu.CompilerParams``; kernels
import the name from here so the tolerance lives in one place.
"""
from jax.experimental.pallas import tpu as pltpu

CompilerParams = getattr(pltpu, "CompilerParams", None) or \
    getattr(pltpu, "TPUCompilerParams")

__all__ = ["CompilerParams"]
