"""Flash attention Pallas kernels for TPU (forward + decode).

Online-softmax over kv blocks with running (max, sum) scratch in VMEM.
Supports causal masking, sliding windows (gemma3's 5:1 local layers) and a
single-query decode variant whose kv-block grid is combined via LSE.

Block geometry again comes from the Covenant tiler
(``tiling.attention_blocks``): the QK^T GEMM's Algorithm-1 tiling is the
flash block structure — this is the hw-codesign point of the reproduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from ._compat import CompilerParams

NEG_INF = -1e30


def _fa_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
               scale: float, causal: bool, window: int | None,
               block_q: int, block_kv: int, seq_k: int, q_offset: int):
    qi = pl.program_id(1)
    kj = pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)          # (bq, d)
    k = k_ref[0].astype(jnp.float32)          # (bkv, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale

    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + q_offset
    kpos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]                        # (bq, 1)
    m_cur = jnp.max(s, axis=-1, keepdims=True)
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)
    p = jnp.where(mask, p, 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == pl.num_programs(2) - 1)
    def _flush():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "block_q", "block_kv", "scale", "q_offset",
    "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    causal: bool = True, window: int | None = None,
                    scale: float | None = None, block_q: int = 128,
                    block_kv: int = 128, q_offset: int | None = None,
                    interpret: bool = False) -> jax.Array:
    """q: (BH, Sq, D); k, v: (BH, Sk, D).  Sq % block_q == 0; Sk padded to
    block_kv by the wrapper (mask uses true seq_k).  ``q_offset`` is the kv
    position of q row 0 (pass ``true_sk - true_sq`` when q is end-padded)."""
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    scale = scale if scale is not None else (d ** -0.5)
    sk_pad = -(-sk // block_kv) * block_kv
    if sk_pad != sk:
        pad = [(0, 0), (0, sk_pad - sk), (0, 0)]
        k = jnp.pad(k, pad)
        v = jnp.pad(v, pad)
    kernel = functools.partial(
        _fa_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, seq_k=sk,
        q_offset=(sk - sq) if q_offset is None else q_offset)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // block_q, sk_pad // block_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def _decode_kernel(q_ref, k_ref, v_ref, len_ref, o_ref, m_ref, l_ref,
                   acc_ref, *, scale: float, block_kv: int):
    kj = pl.program_id(1)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)           # (Hg, d) — grouped q heads
    k = k_ref[0].astype(jnp.float32)           # (bkv, d)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    kpos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < len_ref[0]
    s = jnp.where(mask, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, axis=-1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, axis=-1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == pl.num_programs(1) - 1)
    def _flush():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("scale", "block_kv", "interpret"))
def flash_decode(q: jax.Array, k: jax.Array, v: jax.Array,
                 kv_len: jax.Array, *, scale: float | None = None,
                 block_kv: int = 512, interpret: bool = False) -> jax.Array:
    """Single-token decode attention against a KV cache.

    q: (BKV, Hg, D) — one query block per kv head (Hg = q heads per kv
    head); k, v: (BKV, S, D); kv_len: (BKV,) valid lengths.
    """
    bkv, hg, d = q.shape
    _, s, _ = k.shape
    scale = scale if scale is not None else (d ** -0.5)
    s_pad = -(-s // block_kv) * block_kv
    if s_pad != s:
        k = jnp.pad(k, [(0, 0), (0, s_pad - s), (0, 0)])
        v = jnp.pad(v, [(0, 0), (0, s_pad - s), (0, 0)])
    kernel = functools.partial(_decode_kernel, scale=scale, block_kv=block_kv)
    return pl.pallas_call(
        kernel,
        grid=(bkv, s_pad // block_kv),
        in_specs=[
            pl.BlockSpec((1, hg, d), lambda b, j: (b, 0, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, j: (b, j, 0)),
            pl.BlockSpec((1,), lambda b, j: (b,), memory_space=pltpu.SMEM),
        ],
        out_specs=pl.BlockSpec((1, hg, d), lambda b, j: (b, 0, 0)),
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[
            pltpu.VMEM((hg, 1), jnp.float32),
            pltpu.VMEM((hg, 1), jnp.float32),
            pltpu.VMEM((hg, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, kv_len)


__all__ = ["flash_attention", "flash_attention_bwd",
           "flash_attention_fwd_lse", "flash_decode"]


# ---------------------------------------------------------------------------
# backward kernels (flash recompute; mirrors models/attention.py custom VJP)
# ---------------------------------------------------------------------------


def _fa_bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                      dq_ref, acc_ref, *, scale, causal, window, block_q,
                      block_kv, seq_k, q_offset):
    qi, kj = pl.program_id(1), pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + q_offset
    kpos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    p = jnp.where(mask, jnp.exp(s - lse_ref[0]), 0.0)
    dp = jnp.dot(do_ref[0].astype(jnp.float32), v.T,
                 preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0]) * scale
    acc_ref[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(kj == pl.num_programs(2) - 1)
    def _flush():
        dq_ref[0] = acc_ref[...].astype(dq_ref.dtype)


def _fa_bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, lse_ref, delta_ref,
                       dk_ref, dv_ref, dk_acc, dv_acc, *, scale, causal,
                       window, block_q, block_kv, seq_k, q_offset):
    kj, qi = pl.program_id(1), pl.program_id(2)

    @pl.when(qi == 0)
    def _init():
        dk_acc[...] = jnp.zeros_like(dk_acc)
        dv_acc[...] = jnp.zeros_like(dv_acc)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    v = v_ref[0].astype(jnp.float32)
    do = do_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + q_offset
    kpos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    p = jnp.where(mask, jnp.exp(s - lse_ref[0]), 0.0)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta_ref[0]) * scale
    dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32)
    dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)

    @pl.when(qi == pl.num_programs(2) - 1)
    def _flush():
        dk_ref[0] = dk_acc[...].astype(dk_ref.dtype)
        dv_ref[0] = dv_acc[...].astype(dv_ref.dtype)


def flash_attention_bwd(q, k, v, out, lse, dout, *, causal=True, window=None,
                        scale=None, block_q=128, block_kv=128, seq_k=None,
                        q_offset=0, interpret=False):
    """dq, dk, dv for the flash forward.  All (BH, S, D); lse (BH, S, 1).
    Shapes must be padded to block multiples (ops wrapper handles it)."""
    bh, sq, d = q.shape
    sk = k.shape[1]
    seq_k = sk if seq_k is None else seq_k
    scale = scale if scale is not None else d ** -0.5
    delta = jnp.sum(dout.astype(jnp.float32) * out.astype(jnp.float32), -1,
                    keepdims=True)
    nq, nkv = sq // block_q, sk // block_kv
    common = dict(scale=scale, causal=causal, window=window,
                  block_q=block_q, block_kv=block_kv, seq_k=seq_k,
                  q_offset=q_offset)
    q_spec = pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0))
    kv_spec = pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0))
    row_spec = pl.BlockSpec((1, block_q, 1), lambda b, i, j: (b, i, 0))
    dq = pl.pallas_call(
        functools.partial(_fa_bwd_dq_kernel, **common),
        grid=(bh, nq, nkv),
        in_specs=[q_spec, kv_spec, kv_spec, q_spec, row_spec, row_spec],
        out_specs=q_spec,
        out_shape=jax.ShapeDtypeStruct(q.shape, q.dtype),
        scratch_shapes=[pltpu.VMEM((block_q, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    kv_q_spec = pl.BlockSpec((1, block_q, d), lambda b, j, i: (b, i, 0))
    kv_kv_spec = pl.BlockSpec((1, block_kv, d), lambda b, j, i: (b, j, 0))
    kv_row_spec = pl.BlockSpec((1, block_q, 1), lambda b, j, i: (b, i, 0))
    dk, dv = pl.pallas_call(
        functools.partial(_fa_bwd_dkv_kernel, **common),
        grid=(bh, nkv, nq),
        in_specs=[kv_q_spec, kv_kv_spec, kv_kv_spec, kv_q_spec, kv_row_spec,
                  kv_row_spec],
        out_specs=[kv_kv_spec, kv_kv_spec],
        out_shape=[jax.ShapeDtypeStruct(k.shape, k.dtype),
                   jax.ShapeDtypeStruct(v.shape, v.dtype)],
        scratch_shapes=[pltpu.VMEM((block_kv, d), jnp.float32),
                        pltpu.VMEM((block_kv, d), jnp.float32)],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v, dout, lse, delta)
    return dq, dk, dv


def flash_attention_fwd_lse(q, k, v, *, causal=True, window=None, scale=None,
                            block_q=128, block_kv=128, q_offset=None,
                            interpret=False):
    """Forward that also returns lse (BH, Sq, 1) — the bwd residual."""
    bh, sq, d = q.shape
    _, sk, _ = k.shape
    scale = scale if scale is not None else d ** -0.5
    kernel = functools.partial(
        _fa_fwd_lse_kernel, scale=scale, causal=causal, window=window,
        block_q=block_q, block_kv=block_kv, seq_k=sk,
        q_offset=(sk - sq) if q_offset is None else q_offset)
    return pl.pallas_call(
        kernel,
        grid=(bh, sq // block_q, sk // block_kv),
        in_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
            pl.BlockSpec((1, block_kv, d), lambda b, i, j: (b, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, block_q, d), lambda b, i, j: (b, i, 0)),
            pl.BlockSpec((1, block_q, 1), lambda b, i, j: (b, i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct(q.shape, q.dtype),
                   jax.ShapeDtypeStruct((bh, sq, 1), jnp.float32)],
        scratch_shapes=[
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, 1), jnp.float32),
            pltpu.VMEM((block_q, d), jnp.float32),
        ],
        compiler_params=CompilerParams(
            dimension_semantics=("parallel", "parallel", "arbitrary")),
        interpret=interpret,
    )(q, k, v)


def _fa_fwd_lse_kernel(q_ref, k_ref, v_ref, o_ref, lse_ref, m_ref, l_ref,
                       acc_ref, *, scale, causal, window, block_q, block_kv,
                       seq_k, q_offset):
    qi, kj = pl.program_id(1), pl.program_id(2)

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0].astype(jnp.float32)
    k = k_ref[0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * scale
    qpos = qi * block_q + jax.lax.broadcasted_iota(jnp.int32, s.shape, 0) \
        + q_offset
    kpos = kj * block_kv + jax.lax.broadcasted_iota(jnp.int32, s.shape, 1)
    mask = kpos < seq_k
    if causal:
        mask &= kpos <= qpos
    if window:
        mask &= kpos > qpos - window
    s = jnp.where(mask, s, NEG_INF)
    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, jnp.max(s, -1, keepdims=True))
    p = jnp.where(mask, jnp.exp(s - m_new), 0.0)
    alpha = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * alpha + jnp.sum(p, -1, keepdims=True)
    acc_ref[...] = acc_ref[...] * alpha + jnp.dot(
        p, v_ref[0].astype(jnp.float32), preferred_element_type=jnp.float32)
    m_ref[...] = m_new

    @pl.when(kj == pl.num_programs(2) - 1)
    def _flush():
        l = l_ref[...]
        safe = jnp.where(l == 0.0, 1.0, l)
        o_ref[0] = (acc_ref[...] / safe).astype(o_ref.dtype)
        lse_ref[0] = m_ref[...] + jnp.log(safe)
