"""Public kernel API: padding, block selection, CPU-interpret fallback.

``interpret`` defaults to True on CPU hosts (this container) and False on
real TPU backends; models call these wrappers, never the kernels directly.
Block geometry defaults to the Covenant tiler's Algorithm-1 choice
(``tiling.gemm_blocks`` / ``attention_blocks``).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from . import ref as _ref
from .flash_attention import flash_attention as _fa, flash_decode as _fd
from .matmul import matmul as _mm
from .ssd_scan import ssd_chunk_scan as _ssd
from .tiling import MXU, SUBLANE, attention_blocks, gemm_blocks


def _on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def _interpret(flag) -> bool:
    return (not _on_tpu()) if flag is None else flag


def _pad_to(x: jax.Array, axis: int, mult: int) -> jax.Array:
    s = x.shape[axis]
    t = -(-s // mult) * mult
    if t == s:
        return x
    pads = [(0, 0)] * x.ndim
    pads[axis] = (0, t - s)
    return jnp.pad(x, pads)


def covenant_matmul(a: jax.Array, b: jax.Array, *, out_dtype=None,
                    blocks: tuple[int, int, int] | None = None,
                    interpret: bool | None = None) -> jax.Array:
    """GEMM with Covenant-tiled BlockSpecs; pads to block multiples."""
    m, k = a.shape
    _, n = b.shape
    out_dtype = out_dtype or (
        jnp.int32 if jnp.issubdtype(a.dtype, jnp.integer) else jnp.float32)
    if blocks is None:
        in_dt = "i8" if jnp.issubdtype(a.dtype, jnp.integer) else "bf16"
        blocks = gemm_blocks(m, n, k, in_dtype=in_dt)
    bm, bn, bk = blocks
    ap = _pad_to(_pad_to(a, 0, bm), 1, bk)
    bp = _pad_to(_pad_to(b, 0, bk), 1, bn)
    out = _mm(ap, bp, block_m=bm, block_n=bn, block_k=bk,
              out_dtype=out_dtype, interpret=_interpret(interpret))
    return out[:m, :n]


def covenant_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                       causal: bool = True, window: int | None = None,
                       scale: float | None = None,
                       blocks: tuple[int, int] | None = None,
                       interpret: bool | None = None) -> jax.Array:
    """GQA flash attention.  q: (B,Hq,Sq,D), k/v: (B,Hkv,Sk,D)."""
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        k = jnp.repeat(k, hq // hkv, axis=1)
        v = jnp.repeat(v, hq // hkv, axis=1)
    if blocks is None:
        bq, bkv = attention_blocks(sq, k.shape[2], d)
    else:
        bq, bkv = blocks
    bq = min(bq, -(-sq // SUBLANE) * SUBLANE)
    qf = _pad_to(q.reshape(b * hq, sq, d), 1, bq)
    kf = k.reshape(b * hq, -1, d)
    vf = v.reshape(b * hq, -1, d)
    out = _fa(qf, kf, vf, causal=causal, window=window, scale=scale,
              block_q=bq, block_kv=bkv, q_offset=kf.shape[1] - sq,
              interpret=_interpret(interpret))
    return out[:, :sq].reshape(b, hq, sq, d)


def covenant_decode_attention(q: jax.Array, k: jax.Array, v: jax.Array,
                              kv_len: jax.Array, *,
                              scale: float | None = None,
                              block_kv: int = 512,
                              interpret: bool | None = None) -> jax.Array:
    """One-token GQA decode.  q: (B,Hq,D), cache k/v: (B,Hkv,S,D),
    kv_len: (B,).  Returns (B,Hq,D)."""
    b, hq, d = q.shape
    _, hkv, s, _ = k.shape
    g = hq // hkv
    qg = q.reshape(b * hkv, g, d)
    kf = k.reshape(b * hkv, s, d)
    vf = v.reshape(b * hkv, s, d)
    lens = jnp.repeat(kv_len, hkv)
    out = _fd(qg, kf, vf, lens, scale=scale, block_kv=min(block_kv, s),
              interpret=_interpret(interpret))
    return out.reshape(b, hq, d)


def covenant_ssd(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
                 C: jax.Array, *, chunk: int = 64,
                 init_state: jax.Array | None = None,
                 return_state: bool = False,
                 interpret: bool | None = None):
    """Mamba2 SSD over (b, s, h, p) inputs with (b, s, g, n) B/C."""
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    ck = min(chunk, s)
    spad = -(-s // ck) * ck
    xf = _pad_to(x, 1, ck).transpose(0, 2, 1, 3).reshape(b * h, spad, p)
    dtf = _pad_to(dt, 1, ck).transpose(0, 2, 1).reshape(b * h, spad)
    Bh = jnp.repeat(B, rep, axis=2)
    Ch = jnp.repeat(C, rep, axis=2)
    Bf = _pad_to(Bh, 1, ck).transpose(0, 2, 1, 3).reshape(b * h, spad, n)
    Cf = _pad_to(Ch, 1, ck).transpose(0, 2, 1, 3).reshape(b * h, spad, n)
    Af = jnp.tile(A, b)
    st0 = None
    if init_state is not None:
        st0 = init_state.reshape(b * h, p, n).swapaxes(1, 2)  # (BH,N,P)
    y, fin = _ssd(xf, dtf, Af, Bf, Cf, chunk=ck, init_state=st0,
                  interpret=_interpret(interpret))
    y = y[:, :s].reshape(b, h, s, p).transpose(0, 2, 1, 3)
    if return_state:
        return y, fin.swapaxes(1, 2).reshape(b, h, p, n)
    return y


# re-export oracles for convenience
matmul_ref = _ref.matmul_ref
attention_ref = _ref.attention_ref
ssd_ref = _ref.ssd_ref

__all__ = ["attention_ref", "covenant_attention", "covenant_decode_attention",
           "covenant_matmul", "covenant_ssd", "matmul_ref", "ssd_ref"]
