"""Pure-jnp oracles for every Pallas kernel (the ``ref.py`` contract).

These are the semantics each kernel is tested against (assert_allclose over
shape/dtype sweeps) and the fallbacks model code uses on hosts where the
kernel path is disabled.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def matmul_ref(a: jax.Array, b: jax.Array, out_dtype=jnp.float32) -> jax.Array:
    """C = A @ B with f32 (or i32) accumulation."""
    acc = jnp.int32 if jnp.issubdtype(out_dtype, jnp.integer) else jnp.float32
    return jnp.matmul(a, b, preferred_element_type=acc).astype(out_dtype)


def attention_ref(q: jax.Array, k: jax.Array, v: jax.Array, *,
                  causal: bool = True, window: int | None = None,
                  scale: float | None = None,
                  kv_len: jax.Array | None = None) -> jax.Array:
    """Multi-head attention oracle.

    q: (B, Hq, Sq, D); k, v: (B, Hkv, Sk, D) — GQA handled by head repeat.
    ``window``: sliding-window size (each query attends to the ``window``
    most recent keys, inclusive).  ``kv_len``: optional per-batch valid kv
    length (decode); keys at index >= kv_len are masked.
    """
    b, hq, sq, d = q.shape
    hkv = k.shape[1]
    if hkv != hq:
        rep = hq // hkv
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    scale = scale if scale is not None else (d ** -0.5)
    logits = jnp.einsum("bhqd,bhkd->bhqk", q.astype(jnp.float32),
                        k.astype(jnp.float32)) * scale
    sk = k.shape[2]
    qpos = jnp.arange(sq)[:, None] + (sk - sq)  # align ends (decode offset)
    kpos = jnp.arange(sk)[None, :]
    mask = jnp.ones((sq, sk), bool)
    if causal:
        mask &= kpos <= qpos
    if window is not None:
        mask &= kpos > qpos - window
    if kv_len is not None:
        mask = mask[None] & (kpos[None] < kv_len[:, None, None])
        mask = mask[:, None]  # (B,1,Sq,Sk)
    logits = jnp.where(mask, logits, -jnp.inf)
    p = jax.nn.softmax(logits, axis=-1)
    p = jnp.where(jnp.isnan(p), 0.0, p)  # fully-masked rows
    return jnp.einsum("bhqk,bhkd->bhqd", p, v.astype(jnp.float32)).astype(q.dtype)


def ssd_ref(x: jax.Array, dt: jax.Array, A: jax.Array, B: jax.Array,
            C: jax.Array, D: jax.Array | None = None,
            init_state: jax.Array | None = None,
            return_state: bool = False):
    """Mamba2 SSD oracle: exact sequential recurrence.

    x:  (b, s, h, p)   — inputs per head
    dt: (b, s, h)      — softplus-activated step sizes (>0)
    A:  (h,)           — negative decay rates
    B:  (b, s, g, n)   — input projections (g groups, heads share groups)
    C:  (b, s, g, n)   — output projections
    D:  (h,) skip      — optional
    state: (b, h, p, n)

    h_t = exp(A dt_t) * h_{t-1} + dt_t * B_t x_t^T ;  y_t = h_t C_t
    """
    b, s, h, p = x.shape
    g, n = B.shape[2], B.shape[3]
    rep = h // g
    Bh = jnp.repeat(B, rep, axis=2)  # (b,s,h,n)
    Ch = jnp.repeat(C, rep, axis=2)
    xf = x.astype(jnp.float32)
    dtf = dt.astype(jnp.float32)
    decay = jnp.exp(A.astype(jnp.float32)[None, None, :] * dtf)  # (b,s,h)

    def step(state, inp):
        xt, bt, ct, dct, dtt = inp
        # state: (b,h,p,n)
        state = state * dct[..., None, None] + \
            (dtt[..., None] * xt)[..., None] * bt[:, :, None, :]
        yt = jnp.einsum("bhpn,bhn->bhp", state, ct)
        return state, yt

    s0 = jnp.zeros((b, h, p, n), jnp.float32) if init_state is None \
        else init_state.astype(jnp.float32)
    xs = (xf.transpose(1, 0, 2, 3), Bh.astype(jnp.float32).transpose(1, 0, 2, 3),
          Ch.astype(jnp.float32).transpose(1, 0, 2, 3),
          decay.transpose(1, 0, 2), dtf.transpose(1, 0, 2))
    final, ys = jax.lax.scan(step, s0, xs)
    y = ys.transpose(1, 0, 2, 3)  # (b,s,h,p)
    if D is not None:
        y = y + xf * D.astype(jnp.float32)[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, final
    return y


__all__ = ["attention_ref", "matmul_ref", "ssd_ref"]
