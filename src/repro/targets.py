"""``repro.targets`` — the string-addressable accelerator-target registry.

Everywhere the driver API accepts a target, it accepts a *name* resolved
here: bundled covenant specs (``example``, ``dnnweaver``, ``hvx``,
``tpu_v5e``), specs you ``register()``, and derived-variant names
(``"dnnweaver@pe=32x32"``, ``"hvx@issue_slots=8,VRF.depth=64"``) that
``spec.derive()`` materializes on the fly — the paper's adaptability claim
("design changes without complete compiler redevelopment") as a runnable
sweep over architecture families.

    import repro
    from repro import targets
    from repro.core.spec import acg_spec, scap, scu, sedge, smem, sop

    targets.register(acg_spec("mynpu", memories=[...], computes=[...],
                              edges=[...]))
    art = repro.compile("BERT-LG-GEMM1", "mynpu")          # by name
    art32 = repro.compile("BERT-LG-GEMM1", "mynpu@pe=32x32")  # variant

As a module, it is also the CI ``targets-validate`` entry point::

    PYTHONPATH=src python -m repro.targets            # validate + sweep
    PYTHONPATH=src python -m repro.targets --no-sweep # structural only
"""
from __future__ import annotations

from repro.core.covenant import (CovenantError, CovenantViolation,
                                 check_covenant, validate_acg)
from repro.core.spec import (ACGSpec, SpecError, acg_spec, parse_overrides,
                             validate_spec)
from repro.core.targets import (BUNDLED_SPECS, TARGETS, get_spec, get_target,
                                list_targets, register_spec)

# The facade API: names are the addressing scheme everywhere.
get = get_target
register = register_spec


def list():  # noqa: A001 - deliberate: ``repro.targets.list()`` reads well
    """Sorted names of every registered target."""
    return list_targets()


def derive(name: str, **overrides) -> ACGSpec:
    """Derived variant of a registered target, as a spec:
    ``derive("dnnweaver", pe="32x32")``."""
    return get_spec(name).derive(**overrides)


# ---------------------------------------------------------------------------
# CI: validate every bundled spec + a small derived-variant sweep
# ---------------------------------------------------------------------------


def validate_bundled(sweep: bool = True, emit=print) -> int:
    """Load every bundled spec, run ``validate_spec`` + ``validate_acg``,
    and (optionally) push a 2-variant x 3-layer derived sweep through the
    driver as a smoke test.  Returns the number of problems found."""
    import repro

    problems = 0
    for name, spec in sorted(BUNDLED_SPECS.items()):
        errs = validate_spec(spec, raise_on_error=False)
        if not errs:
            try:
                acg = get_target(name)
            except (SpecError, KeyError) as e:
                errs = getattr(e, "problems", None) or [str(e)]
            else:
                errs = validate_acg(acg, raise_on_error=False)
                if spec.fingerprint() != acg.to_spec().fingerprint():
                    errs.append(
                        "spec does not round-trip through ACG.from_spec")
        for e in errs:
            emit(f"FAIL {name}: {e}")
        problems += len(errs)
        if not errs:
            emit(f"ok   {name}: valid spec, fingerprint "
                 f"{spec.fingerprint()[:12]}, {len(spec.mnemonics)} "
                 f"mnemonics")
    if not sweep:
        return problems
    layers = ["DLRM-FC1", "DLRM-FC2", "DLRM-FC3"]
    # variants chosen to perturb the cost report, not just the key: a PE
    # rescale changes compute granularity, an edge re-rate changes the
    # transfer schedule
    variants = ["dnnweaver@pe=32x32", "hvx@edge.L2.VRF.bandwidth=512"]
    pairs = [(layer, v) for v in variants for layer in layers]
    arts = repro.compile_many(pairs)
    for (layer, variant), art in zip(pairs, arts):
        base = repro.compile(layer, variant.partition("@")[0])
        distinct = art.key != base.key and art.cycles() != base.cycles()
        status = "ok  " if distinct else "FAIL"
        if not distinct:
            problems += 1
        emit(f"{status} {layer} @ {variant}: {art.cycles():.0f} cyc "
             f"(base {base.cycles():.0f}), key {art.key[:12]} vs "
             f"{base.key[:12]}")
    return problems


def _main(argv=None) -> int:
    import argparse

    ap = argparse.ArgumentParser(
        prog="python -m repro.targets",
        description="validate bundled covenant specs (the CI "
                    "targets-validate step)")
    ap.add_argument("--no-sweep", action="store_true",
                    help="skip the derived-variant compile sweep")
    args = ap.parse_args(argv)
    problems = validate_bundled(sweep=not args.no_sweep)
    if problems:
        print(f"targets-validate: {problems} problem(s)")
        return 1
    print("targets-validate: all bundled specs valid")
    return 0


__all__ = [
    "ACGSpec", "BUNDLED_SPECS", "CovenantError", "CovenantViolation",
    "SpecError", "TARGETS", "acg_spec", "check_covenant", "derive", "get",
    "get_spec", "get_target", "list", "list_targets", "parse_overrides",
    "register", "register_spec", "validate_acg", "validate_bundled",
    "validate_spec",
]


if __name__ == "__main__":
    raise SystemExit(_main())
