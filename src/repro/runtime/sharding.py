"""Sharding-rule engine: parameter-path patterns -> PartitionSpec.

MaxText-style logical rules, resolved against the param pytree's key paths.
Defaults implement the production layout for every model family:

* tensor parallelism over ``model``: attention heads, ffn hidden, experts
  (EP), vocab;
* ZeRO-3-style weight sharding over ``data`` on the complementary matrix
  dim (GSPMD inserts the per-layer all-gathers);
* everything small (norms, biases, scalars) replicated;
* batch dims of activations over ``("pod", "data")``.

The leading layer-stack (group) dim of scanned params is automatically
detected and skipped when matching dims.
"""
from __future__ import annotations

import re

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P


def _path_str(path) -> str:
    parts = []
    for k in path:
        if hasattr(k, "key"):
            parts.append(str(k.key))
        elif hasattr(k, "idx"):
            parts.append(str(k.idx))
        else:
            parts.append(str(k))
    return "/".join(parts)


# (regex over path, spec builder for the *trailing* non-stacked dims).
# Specs are given for the logical 2-D (in, out) matrix; stacked leading
# dims get None.  DATA = ZeRO weight-shard axis, MODEL = tensor axis.
RULES: list[tuple[str, tuple]] = [
    (r"embed/tokens$",            ("model", "data")),    # (vocab, d)
    (r"embed/unembed$",           ("data", "model")),    # (d, vocab)
    (r"projector$",               (None, "model")),      # (vis, d)
    (r"pos_enc$",                 (None, None)),
    (r"attn/w[qkv]$",             ("data", "model")),    # (d, heads*hd)
    (r"attn/wo$",                 ("model", "data")),    # (heads*hd, d)
    (r"(xattn)/w[qkv]$",          ("data", "model")),
    (r"(xattn)/wo$",              ("model", "data")),
    (r"mlp/w[ig]$",               ("data", "model")),    # (d, ff)
    (r"mlp/wo$",                  ("model", "data")),    # (ff, d)
    (r"ffn/router$",              (None, None)),         # small, replicated
    # dense (non-expert) layers in the MoE family keep 2-D ffn weights
    (r"dense_layers/.*/ffn/w[ig]$", ("data", "model")),
    (r"dense_layers/.*/ffn/wo$",  ("model", "data")),
    (r"ffn/w[ig]$",               ("model", "data", None)),  # (E, d, ff) EP
    (r"ffn/wo$",                  ("model", None, "data")),  # (E, ff, d)
    (r"ffn/shared/w[ig]$",        ("data", "model")),
    (r"ffn/shared/wo$",           ("model", "data")),
    (r"mamba/in_proj$",           ("data", "model")),
    (r"mamba/out_proj$",          ("model", "data")),
    (r"mamba/conv_w$",            (None, "model")),      # (w, conv_ch)
    (r"mamba/conv_b$",            ("model",)),
    (r"mamba/(A_log|D|dt_bias)$", ("model",)),
    (r"mamba/norm_scale$",        ("model",)),
    (r"shared/w[qkvig]$",         ("data", "model")),    # zamba shared block
    (r"shared/wo(_mlp)?$",        ("model", "data")),
    (r"loras?/.*a$",              ("data", None)),
    (r"loras?/.*b$",              (None, "model")),
    (r"(wi|wg)$",                 ("data", "model")),    # moe dense fallback
    (r"wo$",                      ("model", "data")),
]


def spec_for(path: str, shape: tuple[int, ...],
             rules=None) -> P:
    """PartitionSpec for one leaf; leading stacked dims padded with None."""
    rules = rules if rules is not None else RULES
    for pat, trailing in rules:
        if re.search(pat, path):
            t = tuple(trailing)
            if len(t) > len(shape):
                t = t[-len(shape):] if len(shape) else ()
            lead = (None,) * (len(shape) - len(t))
            spec = lead + t
            # drop axis names on dims not divisible by the mesh axis (the
            # caller re-checks against the actual mesh in shardings())
            return P(*spec)
    return P()  # replicate (norms, scalars)


def shardings(tree, mesh: Mesh, rules=None):
    """NamedShardings for every leaf of ``tree`` (arrays or SDS)."""

    def one(path, leaf):
        spec = spec_for(_path_str(path), tuple(leaf.shape), rules)
        # validate divisibility; drop the axis name where it cannot shard
        fixed = []
        for d, ax in zip(leaf.shape, tuple(spec) + (None,) * leaf.ndim):
            if ax is None:
                fixed.append(None)
                continue
            size = np.prod([mesh.shape[a] for a in
                            ((ax,) if isinstance(ax, str) else ax)])
            fixed.append(ax if d % size == 0 and d >= size else None)
        return NamedSharding(mesh, P(*fixed))

    return jax.tree_util.tree_map_with_path(one, tree)


def batch_spec(mesh: Mesh) -> P:
    axes = tuple(n for n in ("pod", "data") if n in mesh.shape)
    return P(axes if len(axes) > 1 else axes[0])


def batch_shardings(batch_tree, mesh: Mesh):
    """Shard every batch leaf's dim 0 over (pod, data)."""
    bs = tuple(batch_spec(mesh))

    def one(leaf):
        return NamedSharding(mesh, P(*bs, *((None,) * (leaf.ndim - 1))))

    return jax.tree.map(one, batch_tree)


def replicated(mesh: Mesh):
    return NamedSharding(mesh, P())


__all__ = ["RULES", "batch_spec", "batch_shardings", "replicated",
           "shardings", "spec_for"]
