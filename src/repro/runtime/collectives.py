"""Explicit collectives (shard_map): compressed gradient psum + seq-sharded
decode attention with LSE combine.

Most distribution in this framework is implicit (pjit/GSPMD).  Two patterns
need explicit control and are provided here as shard_map primitives:

* ``compressed_psum``   — int8-on-the-wire gradient all-reduce: quantise
  per shard, psum the int8 payload widened to int32 (the sum of n int8
  shards needs log2(n) extra bits), rescale.  Bandwidth on the wire is 1/4
  of f32 psum.
* ``sharded_decode_attention`` — decode attention with the KV cache sharded
  along *sequence*: each shard computes partial (max, sum, acc) over its kv
  slice and the result is combined with a numerically-stable log-sum-exp
  reduction — the distributed flash-decode pattern for kv_heads < |model|.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

# jax moved shard_map around across releases: modern jax exports the
# function at top level; 0.4.x keeps it in jax.experimental.shard_map (and
# in some versions ``jax.shard_map`` resolves to the *module*).
try:
    from jax import shard_map as _shard_map
    shard_map = _shard_map if callable(_shard_map) else _shard_map.shard_map
except (ImportError, AttributeError):
    from jax.experimental.shard_map import shard_map
from jax.sharding import Mesh, PartitionSpec as P

NEG_INF = -1e30


def compressed_psum(grads, mesh: Mesh, axis: str = "data"):
    """All-reduce a grad pytree with int8 payloads (error feedback is the
    optimizer wrapper's job; this is the wire primitive)."""

    def one_allreduce(g):
        def body(gs):
            gf = gs.astype(jnp.float32)
            scale = jnp.max(jnp.abs(gf)) / 127.0 + 1e-12
            q = jnp.clip(jnp.round(gf / scale), -127, 127).astype(jnp.int8)
            qsum = jax.lax.psum(q.astype(jnp.int32), axis)
            smax = jax.lax.pmax(scale, axis)
            return qsum.astype(jnp.float32) * smax

        return shard_map(body, mesh=mesh,
                         in_specs=P(*([None] * g.ndim)),
                         out_specs=P(*([None] * g.ndim)))(g)

    return jax.tree.map(one_allreduce, grads)


def sharded_decode_attention(q, k_cache, v_cache, kv_len, mesh: Mesh,
                             seq_axis: str = "model",
                             scale: float | None = None):
    """q (B,H,D) replicated over ``seq_axis``; caches (B,H,S,D) sharded on
    S.  Returns (B,H,D).  GQA repeat must be done by the caller."""
    b, h, d = q.shape
    s = k_cache.shape[2]
    scale = scale if scale is not None else d ** -0.5
    n_shards = mesh.shape[seq_axis]

    def body(qs, ks, vs, lens):
        # local kv slice: (B,H,S/n,D); global offset of this shard:
        idx = jax.lax.axis_index(seq_axis)
        s_local = ks.shape[2]
        kpos = idx * s_local + jnp.arange(s_local)[None, None]
        logits = jnp.einsum("bhd,bhkd->bhk", qs.astype(jnp.float32),
                            ks.astype(jnp.float32)) * scale
        mask = kpos < lens[:, None, None]
        logits = jnp.where(mask, logits, NEG_INF)
        m = jnp.max(logits, -1, keepdims=True)
        p = jnp.where(mask, jnp.exp(logits - m), 0.0)
        l = p.sum(-1, keepdims=True)
        acc = jnp.einsum("bhk,bhkd->bhd", p, vs.astype(jnp.float32))
        # LSE combine across shards
        g_m = jax.lax.pmax(m, seq_axis)
        alpha = jnp.exp(m - g_m)
        g_l = jax.lax.psum(l * alpha, seq_axis)
        g_acc = jax.lax.psum(acc * alpha[..., 0][..., None], seq_axis)
        return (g_acc / jnp.where(g_l == 0.0, 1.0, g_l)).astype(qs.dtype)

    return shard_map(
        body, mesh=mesh,
        in_specs=(P(), P(None, None, seq_axis, None),
                  P(None, None, seq_axis, None), P()),
        out_specs=P(),
    )(q, k_cache, v_cache, kv_len)


__all__ = ["compressed_psum", "sharded_decode_attention"]
