"""Train/serve step builders: pjit-able pure functions with microbatch
gradient accumulation, donated state, and sharding constraints."""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.optim import Optimizer


def _constrain(tree, shardings):
    if shardings is None:
        return tree
    return jax.tree.map(
        lambda x, s: jax.lax.with_sharding_constraint(x, s), tree, shardings)


def make_loss_with_accum(loss_fn, microbatches: int, grad_shardings=None):
    """Split the per-device batch into ``microbatches`` chunks and
    accumulate grads with a scan — activation memory / microbatches.
    ``grad_shardings`` (param-tree of NamedShardings) pins the accumulator
    carry: without it GSPMD may replicate per-microbatch grads (an 11.7 GiB
    f32 embedding grad per layer on 104B models)."""
    if microbatches <= 1:
        def simple(params, batch):
            loss, grads = jax.value_and_grad(loss_fn)(params, batch)
            return loss, _constrain(grads, grad_shardings)
        return simple

    def accum(params, batch):
        def reshape(x):
            b = x.shape[0]
            assert b % microbatches == 0, (b, microbatches)
            return x.reshape(microbatches, b // microbatches, *x.shape[1:])

        mb = jax.tree.map(reshape, batch)
        gfn = jax.value_and_grad(loss_fn)

        def step(carry, mbatch):
            loss_acc, grads_acc = carry
            loss, grads = gfn(params, mbatch)
            grads = _constrain(grads, grad_shardings)
            return (loss_acc + loss,
                    _constrain(jax.tree.map(jnp.add, grads_acc, grads),
                               grad_shardings)), None

        zero = _constrain(
            jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params),
            grad_shardings)
        (loss, grads), _ = jax.lax.scan(step, (jnp.float32(0), zero), mb)
        inv = 1.0 / microbatches
        return loss * inv, jax.tree.map(lambda g: g * inv, grads)

    return accum


def make_train_step(loss_fn, optimizer: Optimizer, microbatches: int = 1,
                    grad_shardings=None):
    """Returns step(params, opt_state, batch) -> (params, opt_state, metrics)."""
    grad_fn = make_loss_with_accum(loss_fn, microbatches, grad_shardings)

    def train_step(params, opt_state, batch):
        loss, grads = grad_fn(params, batch)
        new_params, new_state, om = optimizer.update(grads, opt_state, params)
        metrics = {"loss": loss, **om}
        return new_params, new_state, metrics

    return train_step


def make_serve_step(model):
    """decode_step as a donated-cache pure function."""

    def serve_step(params, tokens, cache):
        logits, cache = model.decode_step(params, tokens, cache)
        return logits, cache

    return serve_step


__all__ = ["make_loss_with_accum", "make_serve_step", "make_train_step"]
