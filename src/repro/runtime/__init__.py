from . import collectives, fault_tolerance, sharding, trainstep
from .fault_tolerance import LoopReport, StragglerMonitor, train_loop
from .sharding import batch_shardings, shardings, spec_for
from .trainstep import make_serve_step, make_train_step

__all__ = ["LoopReport", "StragglerMonitor", "batch_shardings",
           "collectives", "fault_tolerance", "make_serve_step",
           "make_train_step", "sharding", "shardings", "spec_for",
           "train_loop", "trainstep"]
