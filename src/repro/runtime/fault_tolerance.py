"""Fault-tolerant training loop.

Production behaviours, all testable on one host:

* periodic atomic checkpoints (keep-k) + resume-from-latest on start;
* non-finite loss/grad detection -> roll back to the last checkpoint and
  skip ahead past the poisoned batch;
* failure injection (``inject_failure_at``) to exercise the recovery path;
* straggler monitor: per-step wall-time EMA + z-score; slow steps are
  logged (on real fleets this feeds the scheduler's hot-spare logic —
  here it is observable state the tests assert on).
"""
from __future__ import annotations

import dataclasses
import time
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np

from repro import checkpoint as ckpt


@dataclasses.dataclass
class StragglerMonitor:
    ema: float = 0.0
    var: float = 0.0
    n: int = 0
    threshold: float = 3.0
    slow_steps: list = dataclasses.field(default_factory=list)

    def observe(self, step: int, dt: float) -> bool:
        if self.n >= 5:
            std = max(self.var ** 0.5, 1e-6)
            z = (dt - self.ema) / std
            if z > self.threshold:
                self.slow_steps.append((step, dt, z))
                return True
        # EMA/EVar update (after the z-test so outliers flag first)
        a = 0.2 if self.n else 1.0
        delta = dt - self.ema
        self.ema += a * delta
        self.var = (1 - a) * (self.var + a * delta * delta)
        self.n += 1
        return False


@dataclasses.dataclass
class LoopReport:
    steps_run: int = 0
    rollbacks: int = 0
    resumed_from: int | None = None
    losses: list = dataclasses.field(default_factory=list)
    slow_steps: list = dataclasses.field(default_factory=list)


def train_loop(train_step: Callable, params, opt_state, data_iter,
               *, steps: int, ckpt_dir: str, ckpt_every: int = 50,
               keep: int = 3, inject_failure_at: int | None = None,
               inject_nan_at: int | None = None,
               log_every: int = 10, logger=print) -> tuple:
    """Run ``steps`` optimizer steps with checkpoint/restart + NaN rollback.

    ``data_iter(step) -> batch`` must be random-access (resumable).
    Returns (params, opt_state, LoopReport).
    """
    report = LoopReport()
    state = {"params": params, "opt": opt_state}

    start = 0
    latest = ckpt.latest_step(ckpt_dir)
    if latest is not None:
        state, start, _ = ckpt.load_checkpoint(ckpt_dir, state, latest)
        state = jax.tree.map(jnp.asarray, state)
        report.resumed_from = start
        logger(f"[ft] resumed from checkpoint step {start}")
    else:
        ckpt.save_checkpoint(ckpt_dir, 0, jax.device_get(state), keep=keep)

    monitor = StragglerMonitor()
    step = start
    while step < steps:
        batch = data_iter(step)
        if inject_nan_at is not None and step == inject_nan_at:
            batch = dict(batch)
            first = next(iter(batch))
            batch = {**batch}
            inject_nan_at = None  # only once
            poisoned = np.asarray(batch["weights"], np.float32).copy() \
                if "weights" in batch else None
            if poisoned is not None:
                poisoned[..., 0] = np.nan
                batch["weights"] = poisoned
        t0 = time.perf_counter()
        if inject_failure_at is not None and step == inject_failure_at:
            inject_failure_at = None
            raise _InjectedFailure(step)
        new_params, new_opt, metrics = train_step(state["params"],
                                                  state["opt"], batch)
        loss = float(metrics["loss"])
        dt = time.perf_counter() - t0
        if monitor.observe(step, dt):
            logger(f"[ft] straggler: step {step} took {dt * 1e3:.1f} ms")

        if not np.isfinite(loss):
            report.rollbacks += 1
            latest = ckpt.latest_step(ckpt_dir)
            state, rb_step, _ = ckpt.load_checkpoint(ckpt_dir, state, latest)
            state = jax.tree.map(jnp.asarray, state)
            logger(f"[ft] non-finite loss at step {step}; rolled back to "
                   f"{rb_step}, skipping batch")
            step += 1  # skip the poisoned batch
            continue

        state = {"params": new_params, "opt": new_opt}
        report.losses.append(loss)
        report.steps_run += 1
        step += 1
        if step % ckpt_every == 0 or step == steps:
            ckpt.save_checkpoint(ckpt_dir, step, jax.device_get(state),
                                 keep=keep)
        if step % log_every == 0:
            logger(f"[train] step {step} loss {loss:.4f} "
                   f"({dt * 1e3:.0f} ms)")

    report.slow_steps = monitor.slow_steps
    return state["params"], state["opt"], report


class _InjectedFailure(RuntimeError):
    def __init__(self, step):
        super().__init__(f"injected failure at step {step}")
        self.step = step


InjectedFailure = _InjectedFailure

__all__ = ["InjectedFailure", "LoopReport", "StragglerMonitor", "train_loop"]
