"""zamba2-2.7b [hybrid]: 54 Mamba2 layers d_model=2560 + ONE shared
attention+MLP block (32H kv=32, d_ff=10240, concat(hidden, embed) input,
per-use LoRA r=128) applied every 6 mamba blocks; ssm_state=64.
[arXiv:2411.15242]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="zamba2-2.7b", family="hybrid",
    n_layers=54, d_model=2560, n_heads=32, n_kv_heads=32, d_ff=10240,
    vocab=32_000, norm="rmsnorm", mlp="swiglu",
    ssm_state=64, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1,
    ssm_chunk=512,   # perf-iter C3/C5
    shared_attn_every=6, lora_rank=128,
)

SMOKE = CONFIG.replace(
    n_layers=6, d_model=32, n_heads=4, n_kv_heads=4, d_ff=64, vocab=256,
    ssm_state=8, ssm_headdim=8, ssm_chunk=8, shared_attn_every=3,
    lora_rank=4, param_dtype="float32", compute_dtype="float32")
