"""gemma3-12b [dense]: 48L d_model=3840 16H (GQA kv=8) d_ff=15360
vocab=262144 — 5:1 local:global sliding window (1024), qk-norm, GeGLU,
embed scaling, 128k context.  [hf:google/gemma-3-12b-pt]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="gemma3-12b", family="dense",
    n_layers=48, d_model=3840, n_heads=16, n_kv_heads=8, d_ff=15360,
    vocab=262_144, head_dim=256, norm="rmsnorm", qk_norm=True,
    local_global=(5, 1), window=1024, mlp="geglu", embed_scale=True,
    tie_embeddings=True, rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    n_layers=6, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16, window=8, param_dtype="float32", compute_dtype="float32")
