"""stablelm-12b [dense]: 40L d_model=5120 32H (GQA kv=8) d_ff=13824
vocab=100352 — LayerNorm, partial rotary (25%).
[hf:stabilityai/stablelm-2-12b]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="stablelm-12b", family="dense",
    n_layers=40, d_model=5120, n_heads=32, n_kv_heads=8, d_ff=13824,
    vocab=100_352, norm="layernorm", rope_frac=0.25, mlp="swiglu",
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    param_dtype="float32", compute_dtype="float32")
