"""paligemma-3b [vlm]: 18L d_model=2048 8H (MQA kv=1) d_ff=16384
vocab=257216 — SigLIP frontend STUB (precomputed (B,256,1152) patch
embeddings) + linear projector + gemma decoder.  [arXiv:2407.07726]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="paligemma-3b", family="vlm",
    n_layers=18, d_model=2048, n_heads=8, n_kv_heads=1, d_ff=16384,
    vocab=257_216, head_dim=256, norm="rmsnorm", mlp="gelu",
    embed_scale=True, tie_embeddings=True,
    vis_tokens=256, vis_dim=1152,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=1, d_ff=128, vocab=512,
    head_dim=16, vis_tokens=8, vis_dim=24,
    param_dtype="float32", compute_dtype="float32")
