"""Config registry: ``--arch <id>`` lookup + the assigned input shapes.

Every (arch x shape) pair is a dry-run cell; ``applicable`` encodes the
assignment's skip rules (long_500k needs sub-quadratic attention; see
DESIGN.md §Arch-applicability).
"""
from __future__ import annotations

import dataclasses

from repro.models.config import ArchConfig

from . import (command_r_plus_104b, deepseek_moe_16b, gemma3_12b,
               mamba2_2_7b, olmoe_1b_7b, paligemma_3b, qwen3_0_6b,
               stablelm_12b, whisper_base, zamba2_2_7b)

_MODULES = {
    "command-r-plus-104b": command_r_plus_104b,
    "gemma3-12b": gemma3_12b,
    "stablelm-12b": stablelm_12b,
    "qwen3-0.6b": qwen3_0_6b,
    "deepseek-moe-16b": deepseek_moe_16b,
    "olmoe-1b-7b": olmoe_1b_7b,
    "zamba2-2.7b": zamba2_2_7b,
    "paligemma-3b": paligemma_3b,
    "mamba2-2.7b": mamba2_2_7b,
    "whisper-base": whisper_base,
}

ARCHS = tuple(_MODULES)


def get_config(name: str, smoke: bool = False) -> ArchConfig:
    try:
        mod = _MODULES[name]
    except KeyError as e:
        raise KeyError(f"unknown arch {name!r}; known: {list(ARCHS)}") from e
    return mod.SMOKE if smoke else mod.CONFIG


# ---------------------------------------------------------------------------
# assigned input shapes
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class ShapeSpec:
    name: str
    kind: str          # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = {
    "train_4k": ShapeSpec("train_4k", "train", 4_096, 256),
    "prefill_32k": ShapeSpec("prefill_32k", "prefill", 32_768, 32),
    "decode_32k": ShapeSpec("decode_32k", "decode", 32_768, 128),
    "long_500k": ShapeSpec("long_500k", "decode", 524_288, 1),
}

# archs whose per-token state is sub-quadratic (SSM / hybrid / local-window)
_LONG_OK = {"gemma3-12b", "zamba2-2.7b", "mamba2-2.7b"}


def applicable(arch: str, shape: str) -> tuple[bool, str]:
    """(runs?, reason-if-skipped) for a dry-run cell."""
    if shape == "long_500k" and arch not in _LONG_OK:
        return False, ("pure full attention: 500k KV cache is O(seq) per "
                       "token and O(seq^2) prefill — skipped per assignment")
    return True, ""


def cells(include_skipped: bool = False):
    """All 40 (arch, shape) cells, with skip annotations."""
    out = []
    for a in ARCHS:
        for s in SHAPES:
            ok, why = applicable(a, s)
            if ok or include_skipped:
                out.append((a, s, ok, why))
    return out


__all__ = ["ARCHS", "SHAPES", "ShapeSpec", "applicable", "cells",
           "get_config"]
