"""mamba2-2.7b [ssm]: 64L d_model=2560 attn-free, ssm_state=128,
SSD (state-space duality) chunked scan, vocab=50280.  [arXiv:2405.21060]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="mamba2-2.7b", family="ssm",
    n_layers=64, d_model=2560, n_heads=0, n_kv_heads=0, d_ff=0,
    vocab=50_280, norm="rmsnorm",
    ssm_state=128, ssm_headdim=64, ssm_expand=2, ssm_ngroups=1,
    ssm_chunk=512,   # perf-iter C3/C5: carry traffic ~ 1/chunk
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, vocab=256, ssm_state=16, ssm_headdim=16,
    ssm_chunk=8, param_dtype="float32", compute_dtype="float32")
