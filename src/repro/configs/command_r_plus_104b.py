"""command-r-plus-104b [dense]: 64L d_model=12288 96H (GQA kv=8)
d_ff=33792 vocab=256000 — GQA, no-bias, parallel attn+mlp block,
LayerNorm, tied embeddings.  [hf:CohereForAI/c4ai-command-r-v01]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="command-r-plus-104b", family="dense",
    n_layers=64, d_model=12288, n_heads=96, n_kv_heads=8, d_ff=33792,
    vocab=256_000, head_dim=128, norm="layernorm", parallel_block=True,
    tie_embeddings=True, mlp="swiglu", rope_theta=75_000_000.0,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16, param_dtype="float32", compute_dtype="float32")
