"""qwen3-0.6b [dense]: 28L d_model=1024 16H (GQA kv=8) d_ff=3072
vocab=151936 — qk_norm, head_dim=128, tied embeddings.  [hf:Qwen/Qwen3-0.6B]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="qwen3-0.6b", family="dense",
    n_layers=28, d_model=1024, n_heads=16, n_kv_heads=8, d_ff=3072,
    vocab=151_936, head_dim=128, norm="rmsnorm", qk_norm=True,
    tie_embeddings=True, mlp="swiglu", rope_theta=1_000_000.0,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=2, d_ff=128, vocab=512,
    head_dim=16, param_dtype="float32", compute_dtype="float32")
