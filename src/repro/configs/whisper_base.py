"""whisper-base [audio]: 6L enc + 6L dec, d_model=512 8H (kv=8) d_ff=2048
vocab=51865 — enc-dec; conv frontend STUB (precomputed (B,1500,512) frame
embeddings).  [arXiv:2212.04356]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="whisper-base", family="audio",
    n_layers=6, d_model=512, n_heads=8, n_kv_heads=8, d_ff=2048,
    vocab=51_865, norm="layernorm", mlp="gelu", tie_embeddings=True,
    enc_layers=6, enc_frames=1500,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    enc_layers=2, enc_frames=10,
    param_dtype="float32", compute_dtype="float32")
