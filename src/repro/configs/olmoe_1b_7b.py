"""olmoe-1b-7b [moe]: 16L d_model=2048 16H (kv=16) expert d_ff=1024
vocab=50304, 64 experts top-8, no shared experts.  [arXiv:2409.02060]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="olmoe-1b-7b", family="moe",
    n_layers=16, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=1024,
    vocab=50_304, norm="rmsnorm", mlp="swiglu", qk_norm=True,
    n_experts=64, n_shared_experts=0, top_k=8, moe_d_ff=1024,
)

SMOKE = CONFIG.replace(
    n_layers=2, d_model=64, n_heads=4, n_kv_heads=4, d_ff=32, vocab=512,
    n_experts=8, top_k=2, moe_d_ff=32,
    param_dtype="float32", compute_dtype="float32")
