"""deepseek-moe-16b [moe]: 28L d_model=2048 16H (kv=16) expert d_ff=1408
vocab=102400, 64 routed experts top-6 + 2 shared, fine-grained; first
layer dense (d_ff=10944).  [arXiv:2401.06066]"""
from repro.models.config import ArchConfig

CONFIG = ArchConfig(
    name="deepseek-moe-16b", family="moe",
    n_layers=28, d_model=2048, n_heads=16, n_kv_heads=16, d_ff=10944,
    vocab=102_400, norm="rmsnorm", mlp="swiglu",
    n_experts=64, n_shared_experts=2, top_k=6, moe_d_ff=1408,
    first_dense=1,
)

SMOKE = CONFIG.replace(
    n_layers=3, d_model=64, n_heads=4, n_kv_heads=4, d_ff=128, vocab=512,
    n_experts=8, top_k=2, moe_d_ff=32, first_dense=1,
    param_dtype="float32", compute_dtype="float32")
