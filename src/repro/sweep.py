"""``repro.sweep`` — fleet-scale sweeps over the shared artifact store.

A thin facade over ``repro.core.sweep`` (see that module for the design):
call it as a function *or* run it as a module —

    import repro
    report = repro.sweep(["DLRM-FC1", "DLRM-FC2"],
                         targets=["dnnweaver", "dnnweaver@pe=32x32"],
                         workers=2, store=".repro-store")
    print(report.best_table())

    # the same sweep from the shell (the CI ``sweep-parallel`` job):
    REPRO_CACHE_DIR=.repro-store python -m repro.sweep \
        --layers DLRM-FC1,DLRM-FC2 \
        --targets dnnweaver,dnnweaver@pe=32x32 \
        --workers 2 --assert-unique-compiles

CI contract flags: ``--assert-unique-compiles`` fails unless the sweep
journal shows every work unit compiled *exactly once* (across cold + warm
runs of the same plan); ``--expect-store-hits`` fails unless every unit
was served from the store with zero pipeline stages executed (the warm
re-run check).  ``--external`` makes this process one claim-based worker
of an independently launched fleet instead of a forking coordinator.
"""
from __future__ import annotations

import sys
import types

from repro.core.store import ArtifactStore, SweepJournal, WarmStartIndex
from repro.core.sweep import (SweepReport, UnitResult, WorkUnit,
                              expand_plan, partition, plan_id,
                              run_external_worker, sweep, workload_of)

__all__ = ["ArtifactStore", "SweepJournal", "SweepReport", "UnitResult",
           "WarmStartIndex", "WorkUnit", "expand_plan", "partition",
           "plan_id", "run_external_worker", "sweep", "workload_of"]


class _CallableModule(types.ModuleType):
    """``import repro.sweep`` rebinds the ``repro.sweep`` attribute from
    the function exported by ``repro/__init__`` to this module; making the
    module itself callable keeps ``repro.sweep(...)`` working either way."""

    def __call__(self, *args, **kwargs):
        return sweep(*args, **kwargs)


sys.modules[__name__].__class__ = _CallableModule


# ---------------------------------------------------------------------------
# CLI — the CI ``sweep-parallel`` entry point
# ---------------------------------------------------------------------------


def _parse_search(text: str):
    """``strategy=beam,generations=4,population=10,beam_width=8,
    warm_start=1`` -> SearchOptions; a bare strategy name is shorthand
    (``beam`` == ``strategy=beam``)."""
    from repro.core.search import STRATEGIES, SearchOptions
    kwargs: dict = {}
    for part in text.split(","):
        if not part:
            continue
        k, _, v = part.partition("=")
        k = k.strip()
        if not v:
            if k in STRATEGIES:
                kwargs["strategy"] = k
                continue
            raise ValueError(
                f"--search: {k!r} is neither a registered strategy "
                f"({sorted(STRATEGIES)}) nor a K=V setting")
        if k == "strategy":
            kwargs[k] = v.strip()
        elif k == "warm_start":
            kwargs[k] = v.strip().lower() in ("1", "true", "yes")
        elif k == "patience":
            kwargs[k] = None if v.strip().lower() == "none" else int(v)
        else:
            try:
                kwargs[k] = int(v)
            except ValueError:
                raise ValueError(
                    f"--search: {k}={v!r} is not an integer") from None
    try:
        return SearchOptions(**kwargs)
    except TypeError as e:
        raise ValueError(f"--search: {e}") from None


def _main(argv=None) -> int:
    import argparse
    import os

    from repro.core import library, store as store_mod

    ap = argparse.ArgumentParser(
        prog="python -m repro.sweep",
        description="shard a (layers x target-variants) compile sweep "
                    "across worker processes over a shared artifact store")
    ap.add_argument("--layers", default=None,
                    help="comma list of paper-layer keys "
                         "(default: every Table-2 layer)")
    ap.add_argument("--targets", default="hvx,dnnweaver",
                    help="comma list of registry names, incl. derived "
                         "variants like dnnweaver@pe=32x32")
    ap.add_argument("--workers", type=int, default=1)
    ap.add_argument("--backend", default=None,
                    choices=("serial", "process", "external"))
    ap.add_argument("--external", action="store_true",
                    help="act as one claim-based worker of an "
                         "independently launched fleet")
    ap.add_argument("--store", default=None,
                    help="artifact-store directory "
                         "(default: $REPRO_CACHE_DIR)")
    ap.add_argument("--search", action="append", default=None,
                    metavar="K=V,...",
                    help="add a search axis entry (repeatable), e.g. "
                         "'strategy=evolutionary,generations=4,"
                         "population=10,seed=0' or just 'beam'; repeat "
                         "the flag to race several strategies")
    ap.add_argument("--race", action="store_true",
                    help="race the --search strategies per (layer, "
                         "target) under equal budgets and pin each "
                         "winner in the store")
    ap.add_argument("--stale-claim-timeout", type=float, default=60.0)
    ap.add_argument("--no-dedup", action="store_true",
                    help="dispatch already-stored units anyway (they "
                         "still warm-restore inside the workers)")
    ap.add_argument("--gc-max-age", type=float, default=None, metavar="S",
                    help="age-GC the store before sweeping")
    ap.add_argument("--json", default=None, metavar="PATH",
                    help="write the SweepReport as JSON")
    ap.add_argument("--assert-unique-compiles", action="store_true",
                    help="fail unless the sweep journal shows every work "
                         "unit compiled exactly once")
    ap.add_argument("--expect-store-hits", action="store_true",
                    help="fail unless every unit came from the store with "
                         "zero pipeline stages executed (warm-run check)")
    args = ap.parse_args(argv)

    layers = args.layers.split(",") if args.layers \
        else [s.key for s in library.PAPER_LAYERS]
    targets = args.targets.split(",")
    store = args.store or os.environ.get(store_mod.ENV_DIR)
    needs_store = (args.external or args.backend == "external"
                   or args.assert_unique_compiles
                   or args.expect_store_hits or args.workers > 1
                   or args.race)
    if store is None and needs_store:
        print("error: multi-worker / journal-asserted / racing sweeps need "
              "a store (--store DIR or REPRO_CACHE_DIR)", file=sys.stderr)
        return 2
    st = store_mod.resolve(store) if store else None
    if st is not None and args.gc_max_age is not None:
        print(f"gc: {st.gc(max_age=args.gc_max_age)}")
    try:
        searches = [_parse_search(s) for s in args.search] if args.search \
            else None
    except ValueError as e:
        print(f"error: {e}", file=sys.stderr)
        return 2
    if args.race and (not searches or len(searches) < 2):
        print("error: --race needs at least two --search strategies",
              file=sys.stderr)
        return 2
    backend = args.backend or ("external" if args.external else None)

    report = sweep(layers, targets, searches=searches, workers=args.workers,
                   store=st, backend=backend, dedup=not args.no_dedup,
                   race=args.race,
                   stale_claim_timeout=args.stale_claim_timeout)

    for r in report.results:
        cyc = f"{r.cycles:.0f}" if r.cycles is not None else "-"
        line = (f"{r.status:7s} {r.source:8s} {r.worker:12s} "
                f"{r.layer} @ {r.target} [{r.opt}] cycles={cyc}")
        if r.error:
            line += f" error={r.error}"
        print(line)
    print()
    print(report.best_table())
    if args.race:
        print()
        print(report.race_table())
    print()
    print(report.summary())
    if args.json:
        report.save(args.json)

    failures = 0
    if report.counts()["failed"]:
        print(f"FAIL: {report.counts()['failed']} unit(s) failed",
              file=sys.stderr)
        failures += 1
    if args.assert_unique_compiles:
        counts = st.journal(report.sweep_id).compile_counts()
        dupes = {k: n for k, n in counts.items() if n != 1}
        missing = [r.key for r in report.results
                   if r.key not in counts and r.source == "compiled"]
        if dupes or missing:
            print(f"FAIL: journal shows non-unique compiles "
                  f"(dupes={dupes}, unjournaled={missing})",
                  file=sys.stderr)
            failures += 1
        else:
            print(f"journal: {len(counts)} unit(s) compiled exactly once")
    if args.expect_store_hits:
        cold = [r for r in report.results
                if r.source not in ("store", "dedup")]
        stages = report.stages_run()
        if cold or stages:
            print(f"FAIL: expected an all-store warm sweep, but "
                  f"{len(cold)} unit(s) (re)compiled and {stages} "
                  f"pipeline stage(s) ran", file=sys.stderr)
            failures += 1
        else:
            print(f"warm: all {len(report.results)} units served from the "
                  f"store, zero pipeline stages executed")
    return 1 if failures else 0


if __name__ == "__main__":
    raise SystemExit(_main())
