from .collect import collect_compiled, collective_bytes
from .model import (HBM_BW, ICI_BW, PEAK_FLOPS, Roofline, model_flops,
                    param_count, roofline_terms)

__all__ = ["HBM_BW", "ICI_BW", "PEAK_FLOPS", "Roofline", "collect_compiled",
           "collective_bytes", "model_flops", "param_count",
           "roofline_terms"]
