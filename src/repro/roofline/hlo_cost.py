"""Trip-count-aware HLO cost model.

XLA's ``compiled.cost_analysis()`` counts every ``while`` body ONCE,
regardless of trip count — useless for scanned layer stacks (a 64-layer
model reports ~1 layer of FLOPs).  This parser walks the optimized HLO
text, prices each computation (dot FLOPs exactly; elementwise/reduce
approximately; operand+result bytes for memory traffic), then expands the
call graph with real trip counts:

* ``while`` trips come from ``backend_config={"known_trip_count":{"n":N}}``
  (XLA annotates lax.scan loops), falling back to the condition
  computation's ``compare(iv, constant(N))``;
* fusions/calls/custom-calls expand their called computations once;
* all numbers are per-device (the module is the SPMD-partitioned program).

Validated against known-size scans in tests/test_roofline.py.
"""
from __future__ import annotations

import dataclasses
import math
import re

_SHAPE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "pred": 1, "s4": 1, "u4": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2,
    "bf16": 2, "f16": 2, "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8,
    "f64": 8, "c64": 8, "f8e4m3fn": 1, "f8e5m2": 1, "u64": 8,
}

_ELEMENTWISE = {
    "add", "subtract", "multiply", "divide", "maximum", "minimum", "abs",
    "negate", "exponential", "exponential-minus-one", "log", "log-plus-one",
    "tanh", "rsqrt", "sqrt", "power", "floor", "ceil", "round-nearest-afz",
    "select", "compare", "and", "or", "xor", "not", "sign", "cosine", "sine",
    "clamp", "atan2", "convert",
}

_FREE = {"parameter", "get-tuple-element", "tuple", "bitcast", "constant",
         "after-all", "iota", "partition-id", "replica-id",
         "opt-barrier", "custom-call"}

_COLLECTIVE_PREFIX = ("all-gather", "all-reduce", "reduce-scatter",
                      "all-to-all", "collective-permute")

# NOTE: the result type may be a long tuple containing /*index=N*/ comments
# (which contain '='), so the type group must be a lazy dot-match.
_INSTR = re.compile(
    r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+?)\s+([a-z][a-z0-9\-]*)\((.*)$")
_TRIP = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_BODY = re.compile(r"body=%?([\w.\-]+)")
_COND = re.compile(r"condition=%?([\w.\-]+)")
_CALLS = re.compile(r"(?:calls=|to_apply=)%?([\w.\-]+)")


def _elems_bytes(typestr: str) -> tuple[int, int]:
    elems = bts = 0
    for dt, dims in _SHAPE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        elems += n
        bts += n * _DTYPE_BYTES[dt]
    return elems, bts


# one TPU-v5e core's usable VMEM share for inter-op residency; individual
# tensors at or below this size are assumed to stay on-chip between ops.
VMEM_RESIDENT_BYTES = 4 * 2**20


def _hbm_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        b = n * _DTYPE_BYTES[dt]
        if b > VMEM_RESIDENT_BYTES:
            total += b
    return total


def _split_call(rest: str) -> tuple[str, str]:
    """'operands), attrs' -> (operands, attrs); handles nested parens."""
    depth = 0
    for i, ch in enumerate(rest):
        if ch == "(":
            depth += 1
        elif ch == ")":
            if depth == 0:
                return rest[:i], rest[i + 1:]
            depth -= 1
    return rest, ""


@dataclasses.dataclass
class Comp:
    name: str
    flops: float = 0.0
    bytes: float = 0.0
    coll_bytes: float = 0.0
    sites: list = dataclasses.field(default_factory=list)  # (mult?, callee)
    whiles: list = dataclasses.field(default_factory=list)  # (body, cond, trips|None)
    consts: dict = dataclasses.field(default_factory=dict)
    compare_ops: list = dataclasses.field(default_factory=list)


def parse_computations(hlo: str) -> tuple[dict, str | None]:
    comps: dict[str, Comp] = {}
    entry = None
    cur: Comp | None = None
    types: dict[str, str] = {}
    for raw in hlo.splitlines():
        s = raw.strip()
        header = re.match(r"^(ENTRY\s+)?%([\w.\-]+)\s*\(.*\)\s*->", s)
        if header and s.endswith("{"):
            cur = Comp(header.group(2))
            comps[cur.name] = cur
            types = {}
            if header.group(1):
                entry = cur.name
            continue
        if cur is None:
            continue
        m = _INSTR.match(raw)
        if not m:
            continue
        name, rtype, op, rest = m.groups()
        operands, attrs = _split_call(rest)
        types[name] = rtype
        relems, rbytes = _elems_bytes(rtype)

        if op == "constant":
            if re.fullmatch(r"-?[0-9]+", operands.strip()):
                cur.consts[name] = int(operands.strip())
            continue
        if op in _FREE and op != "custom-call":
            if op == "parameter" or op == "get-tuple-element":
                continue
            continue

        opnames = re.findall(r"%([\w.\-]+)", operands)
        if op not in ("while", "conditional"):
            # loop carries are buffer-aliased in place, not re-read per
            # surface; the body's own ops already price their traffic.
            # HBM-residency threshold: tensors small enough to live in VMEM
            # between ops (flash blocks, norm stats, masks) are priced zero
            # — the TPU hierarchy keeps them on-chip, and counting them
            # would make every blocked kernel look memory-bound.
            cur.bytes += _hbm_bytes(rtype) + sum(
                _hbm_bytes(types.get(o, "")) for o in opnames)

        if op == "dot":
            mm = re.search(r"lhs_contracting_dims=\{([0-9,]*)\}", attrs)
            lhs_type = types.get(opnames[0], "") if opnames else ""
            lhs_shapes = _SHAPE.findall(lhs_type)
            lhs_dims = [int(d) for d in lhs_shapes[0][1].split(",") if d] \
                if lhs_shapes else []
            cdims = [int(x) for x in mm.group(1).split(",") if x] if mm else \
                ([len(lhs_dims) - 1] if lhs_dims else [])
            k = math.prod([lhs_dims[c] for c in cdims
                           if c < len(lhs_dims)]) or 1
            cur.flops += 2.0 * relems * k
        elif op == "convolution":
            kelems = 1
            if len(opnames) > 1:
                kshapes = _SHAPE.findall(types.get(opnames[1], ""))
                if kshapes:
                    kd = [int(d) for d in kshapes[0][1].split(",") if d]
                    kelems = math.prod(kd[:-1]) if kd else 1
            cur.flops += 2.0 * relems * kelems
        elif op in _ELEMENTWISE:
            cur.flops += relems
            if op == "compare" and "direction=LT" in attrs:
                cur.compare_ops.append(opnames)
        elif op in ("reduce", "reduce-window"):
            oelems = sum(_elems_bytes(types.get(o, ""))[0]
                         for o in opnames[:1])
            cur.flops += oelems
        if op.startswith(_COLLECTIVE_PREFIX) and not op.endswith("-done"):
            cur.coll_bytes += rbytes

        if op == "while":
            body = _BODY.search(attrs)
            cond = _COND.search(attrs)
            trip = _TRIP.search(attrs)
            cur.whiles.append((body.group(1) if body else None,
                               cond.group(1) if cond else None,
                               int(trip.group(1)) if trip else None))
        elif op in ("fusion", "call", "map", "custom-call", "reduce",
                    "reduce-window", "sort", "scatter", "select-and-scatter"):
            cm = _CALLS.search(attrs)
            if cm:
                cur.sites.append(cm.group(1))
    return comps, entry


def _trips_from_cond(comps: dict, cond_name: str | None) -> int:
    if cond_name is None or cond_name not in comps:
        return 1
    cond = comps[cond_name]
    for opnames in cond.compare_ops:
        for o in opnames:
            if o in cond.consts:
                return max(1, cond.consts[o])
    # the compare may live in a fused computation inside the cond
    for callee in cond.sites:
        sub = comps.get(callee)
        if sub and sub.compare_ops:
            for o in cond.consts.values():
                return max(1, o)
    if cond.consts:
        return max(1, max(cond.consts.values()))
    return 1


def _expand(comps: dict, name: str, memo: dict) -> tuple[float, float, float]:
    if name in memo:
        return memo[name]
    memo[name] = (0.0, 0.0, 0.0)
    c = comps.get(name)
    if c is None:
        return 0.0, 0.0, 0.0
    f, b, cb = c.flops, c.bytes, c.coll_bytes
    for callee in c.sites:
        # fusion/call bodies are register-resident: count their FLOPs and
        # collectives, but HBM bytes only at the fusion surface (already
        # priced as the caller's operand/result bytes).
        cf, _cbts, ccoll = _expand(comps, callee, memo)
        f += cf
        cb += ccoll
    for body, cond, trips in c.whiles:
        mult = trips if trips is not None else _trips_from_cond(comps, cond)
        bf, bb, bcoll = _expand(comps, body, memo) if body else (0, 0, 0)
        f += mult * bf
        b += mult * bb
        cb += mult * bcoll
    memo[name] = (f, b, cb)
    return memo[name]


def analyze(hlo_text: str) -> dict:
    """Per-device {flops, bytes, collective_bytes} with loops expanded."""
    comps, entry = parse_computations(hlo_text)
    if entry is None:
        entry = max(comps, key=lambda n: comps[n].flops, default=None)
    f, b, cb = _expand(comps, entry, {}) if entry else (0.0, 0.0, 0.0)
    return {"flops": f, "bytes": b, "collective_bytes": cb,
            "n_computations": len(comps)}


__all__ = ["analyze", "parse_computations"]
