"""Three-term roofline model for TPU v5e (targets; container is CPU-only).

    compute    = HLO_FLOPs / (chips x 197e12 bf16 FLOP/s)
    memory     = HLO_bytes / (chips x 819e9 B/s HBM)
    collective = collective_bytes / (chips x 50e9 B/s ICI link)

HLO FLOPs/bytes/collective-bytes all come from the trip-count-expanded
parser (hlo_cost.py) over the SPMD per-device module, so every term is
PER-DEVICE and the chips factor is already folded in — the formulas below
divide by one chip's peak.  MODEL_FLOPS = 6·N·D for training (fwd+bwd) and
2·N_active·D for single forward passes; attention FLOPs added explicitly.
"""
from __future__ import annotations

import dataclasses

PEAK_FLOPS = 197e12      # bf16 / chip
HBM_BW = 819e9           # B/s / chip
ICI_BW = 50e9            # B/s / link (one link direction counted)


@dataclasses.dataclass
class Roofline:
    compute_s: float
    memory_s: float
    collective_s: float

    @property
    def bottleneck(self) -> str:
        terms = {"compute": self.compute_s, "memory": self.memory_s,
                 "collective": self.collective_s}
        return max(terms, key=terms.get)

    @property
    def step_s(self) -> float:
        return max(self.compute_s, self.memory_s, self.collective_s)

    @property
    def compute_fraction(self) -> float:
        """Fraction of the roofline-ideal step spent at peak compute — the
        score we hillclimb (1.0 = perfectly compute-bound at peak)."""
        return self.compute_s / max(self.step_s, 1e-30)


def roofline_terms(record: dict) -> Roofline:
    # all inputs are per-device (SPMD module, trip-expanded)
    return Roofline(
        compute_s=record["flops"] / PEAK_FLOPS,
        memory_s=record["bytes_accessed"] / HBM_BW,
        collective_s=record["collective_bytes"] / ICI_BW,
    )


# ---------------------------------------------------------------------------
# model FLOPs (analytic, for the useful-compute ratio)
# ---------------------------------------------------------------------------


def param_count(cfg) -> tuple[float, float]:
    """(total, active-per-token) parameter counts."""
    d, L = cfg.d_model, cfg.n_layers
    hd = cfg.hd
    emb = cfg.vocab * d * (1 if cfg.tie_embeddings else 2)
    total = active = emb
    if cfg.family in ("dense", "vlm"):
        attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + \
            cfg.n_heads * hd * d
        glu = 3 if cfg.mlp in ("swiglu", "geglu") else 2
        mlp = glu * d * cfg.d_ff
        total += L * (attn + mlp)
        active = total
        if cfg.family == "vlm":
            total += cfg.vis_dim * d
            active = total
    elif cfg.family == "moe":
        attn = d * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd + \
            cfg.n_heads * hd * d
        ff = cfg.moe_d_ff or cfg.d_ff
        expert = 3 * d * ff
        shared = 3 * d * ff * cfg.n_shared_experts
        router = d * cfg.n_experts
        n_moe = L - cfg.first_dense
        total += L * attn + cfg.first_dense * 3 * d * cfg.d_ff + \
            n_moe * (cfg.n_experts * expert + shared + router)
        active = emb + L * attn + cfg.first_dense * 3 * d * cfg.d_ff + \
            n_moe * (cfg.top_k * expert + shared + router)
    elif cfg.family in ("ssm", "hybrid"):
        di = cfg.ssm_d_inner
        gn = cfg.ssm_ngroups * cfg.ssm_state
        mamba = d * (2 * di + 2 * gn + cfg.ssm_nheads) + di * d + \
            cfg.ssm_conv * (di + 2 * gn)
        n_mamba = L
        total += n_mamba * mamba
        active = total
        if cfg.family == "hybrid":
            da = 2 * d
            hd2 = da // cfg.n_heads
            shared_blk = da * (cfg.n_heads + 2 * cfg.n_kv_heads) * hd2 + \
                cfg.n_heads * hd2 * d + 2 * da * cfg.d_ff + cfg.d_ff * d
            n_groups = L // cfg.shared_attn_every
            lora = n_groups * cfg.lora_rank * (
                2 * da + cfg.n_heads * hd2 + cfg.d_ff)
            total += shared_blk + lora
            active = total
    elif cfg.family == "audio":
        attn = 4 * d * d
        mlp = 2 * d * cfg.d_ff
        total += cfg.enc_layers * (attn + mlp) + L * (2 * attn + mlp) + \
            cfg.enc_frames * d
        active = total
    return float(total), float(active)


def model_flops(cfg, shape, kind: str) -> float:
    """Analytic useful FLOPs for one step of this cell."""
    total, active = param_count(cfg)
    tokens = shape.global_batch * (shape.seq_len if kind != "decode" else 1)
    mult = 6.0 if kind == "train" else 2.0
    flops = mult * active * tokens
    # attention (quadratic part), forward only; x3 for train
    if cfg.family in ("dense", "moe", "vlm", "audio"):
        s = shape.seq_len
        att = 2 * 2 * shape.global_batch * cfg.n_heads * cfg.hd * (
            s * s / 2 if kind != "decode" else s)
        local, glob = cfg.local_global
        if local + glob > 0 and cfg.window:
            frac_local = local / (local + glob)
            att = att * (1 - frac_local) + frac_local * 2 * 2 * \
                shape.global_batch * cfg.n_heads * cfg.hd * \
                (s * min(cfg.window, s) if kind != "decode"
                 else min(cfg.window, s))
        flops += cfg.n_layers * att * (3 if kind == "train" else 1)
    return float(flops)


__all__ = ["HBM_BW", "ICI_BW", "PEAK_FLOPS", "Roofline", "model_flops",
           "param_count", "roofline_terms"]
