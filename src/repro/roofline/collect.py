"""Extract roofline terms from a compiled AOT executable.

* ``cost_analysis()``      -> HLO FLOPs + bytes accessed
* ``memory_analysis()``    -> per-device HBM proof (args/outputs/temps)
* optimized HLO text       -> collective bytes: summed operand sizes of
  all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
  (cost_analysis does not report these).
"""
from __future__ import annotations

import re

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "s16": 2, "u16": 2, "bf16": 2, "f16": 2,
    "s32": 4, "u32": 4, "f32": 4, "s64": 8, "u64": 8, "f64": 8, "c64": 8,
    "f8e4m3fn": 1, "f8e5m2": 1,
}

_COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
                "collective-permute")

# e.g.  "bf16[8,256,1536]{2,1,0} all-gather(...)" — capture result type +
# op name; operand types appear inside parens for some ops, so we use the
# *result* shape per collective (a standard, consistent proxy: AG result =
# gathered bytes moved; AR result = reduced tensor; A2A result = moved).
_HLO_RE = re.compile(
    r"(?:ROOT\s+)?%?[\w.\-]+\s*=\s*"
    r"\(?((?:[a-z0-9]+\[[0-9,]*\][^\s)]*)(?:,\s*[a-z0-9]+\[[0-9,]*\][^\s)]*)*)\)?"
    r"\s+([a-z\-]+)\(")

_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(typestr: str) -> int:
    total = 0
    for dt, dims in _SHAPE_RE.findall(typestr):
        if dt not in _DTYPE_BYTES:
            continue
        n = 1
        for d in dims.split(","):
            if d:
                n *= int(d)
        total += n * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> tuple[float, dict]:
    """Total collective bytes (per device) + per-op-kind breakdown."""
    per_kind: dict[str, float] = {}
    for m in _HLO_RE.finditer(hlo_text):
        types, op = m.group(1), m.group(2)
        kind = next((c for c in _COLLECTIVES if op.startswith(c)), None)
        if kind is None:
            continue
        # "-start" variants carry the payload; "-done" repeats the type.
        if op.endswith("-done"):
            continue
        per_kind[kind] = per_kind.get(kind, 0.0) + _shape_bytes(types)
    return sum(per_kind.values()), per_kind


def collect_compiled(compiled, lowered=None) -> dict:
    ca = compiled.cost_analysis() or {}
    if isinstance(ca, list):
        ca = ca[0] if ca else {}
    flops = float(ca.get("flops", 0.0))
    bytes_accessed = float(ca.get("bytes accessed", 0.0))
    ma = compiled.memory_analysis()
    rec = {
        "flops": flops,
        "bytes_accessed": bytes_accessed,
        "bytes_per_device": float(
            getattr(ma, "argument_size_in_bytes", 0)
            + getattr(ma, "output_size_in_bytes", 0)
            + getattr(ma, "temp_size_in_bytes", 0)
            - getattr(ma, "alias_size_in_bytes", 0)),
        "argument_bytes": float(getattr(ma, "argument_size_in_bytes", 0)),
        "output_bytes": float(getattr(ma, "output_size_in_bytes", 0)),
        "temp_bytes": float(getattr(ma, "temp_size_in_bytes", 0)),
        "alias_bytes": float(getattr(ma, "alias_size_in_bytes", 0)),
        "generated_code_bytes": float(
            getattr(ma, "generated_code_size_in_bytes", 0)),
    }
    try:
        text = compiled.as_text()
    except Exception:
        text = lowered.as_text() if lowered is not None else ""
    total, per_kind = collective_bytes(text)
    rec["collective_breakdown"] = per_kind
    rec["n_collectives"] = {
        k: text.count(f" {k}") for k in _COLLECTIVES}

    # trip-count-expanded per-device costs (cost_analysis counts while
    # bodies once — see hlo_cost.py); these are the roofline inputs.
    from .hlo_cost import analyze
    expanded = analyze(text)
    rec["flops_raw_costanalysis"] = rec.pop("flops")
    rec["bytes_raw_costanalysis"] = rec.pop("bytes_accessed")
    rec["collective_bytes_raw"] = total
    rec["flops"] = expanded["flops"]                 # per device
    rec["bytes_accessed"] = expanded["bytes"]        # per device
    rec["collective_bytes"] = expanded["collective_bytes"]  # per device
    return rec


__all__ = ["collect_compiled", "collective_bytes"]
