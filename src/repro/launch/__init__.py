"""Launch layer: production mesh factory, AOT dry-run, train/serve drivers.

NOTE: ``dryrun`` is intentionally NOT imported here — importing it sets
XLA_FLAGS (512 fake devices) which must never leak into tests/benches.
"""
from . import mesh, specs
from .mesh import make_host_mesh, make_production_mesh

__all__ = ["make_host_mesh", "make_production_mesh", "mesh", "specs"]
