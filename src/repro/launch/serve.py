"""Serving driver: batched prefill + decode with a continuous request
queue.  ``python -m repro.launch.serve --arch qwen3-0.6b --smoke``.

Implements a minimal production serving loop: a batch of requests is
prefixed (prefill), then decoded step-by-step with the KV cache donated
between steps; finished sequences (EOS or max tokens) are retired and
their slots refilled from the queue (continuous batching).

Layer compilation is routed through the unified driver: before serving,
the model's decode-shape GEMMs are compiled with ``repro.compile`` for
``--accel-target`` (optionally with ``--accel-search`` schedule search)
and the per-layer accelerator cycle report is printed.  With
``REPRO_CACHE_DIR`` set, repeated launches replay these compiles from the
disk artifact store.
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

import repro
from repro import configs
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                               use_mesh)
from repro.models import get_model


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--max-new", type=int, default=32)
    ap.add_argument("--requests", type=int, default=12)
    ap.add_argument("--max-len", type=int, default=96)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--accel-target", default="hvx",
                    help="Covenant target name for the layer-compile report: "
                         "any repro.targets name, incl. derived variants "
                         "like 'dnnweaver@pe=32x32' ('none' disables it)")
    ap.add_argument("--accel-search", action="store_true",
                    help="schedule-search the layer compiles "
                         "(CompileOptions(search=...))")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    if args.accel_target != "none":
        from repro.launch.layers import layer_report
        opts = repro.CompileOptions(
            search=repro.SearchOptions(generations=3, population=8)
            if args.accel_search else None)
        print(layer_report(cfg, tokens=args.batch,
                           target=args.accel_target, options=opts))
    mesh = make_host_mesh() if args.smoke else make_production_mesh()
    rng = np.random.default_rng(args.seed)

    with use_mesh(mesh):
        params = model.init_params(jax.random.PRNGKey(args.seed))
        decode = jax.jit(model.decode_step, donate_argnums=(2,))

        def new_prompt():
            return rng.integers(2, cfg.vocab, args.prompt_len)

        served = 0
        total_tokens = 0
        t0 = time.perf_counter()
        queue = [new_prompt() for _ in range(args.requests)]
        while queue:
            batch_prompts = [queue.pop() for _ in
                             range(min(args.batch, len(queue)))]
            bs = len(batch_prompts)
            toks = jnp.asarray(np.stack(batch_prompts), jnp.int32)
            batch = {"tokens": toks}
            for name, (shape_fn, dtype) in model.extra_inputs.items():
                batch[name] = jnp.asarray(
                    rng.standard_normal(shape_fn(bs, args.prompt_len)),
                    dtype)
            cache = model.init_cache(bs, args.max_len)
            logits, cache = model.prefill(params, batch, cache)
            tok = jnp.argmax(logits, -1).astype(jnp.int32)
            done = np.zeros(bs, bool)
            for _ in range(args.max_new):
                logits, cache = decode(params, tok, cache)
                tok = jnp.argmax(logits, -1).astype(jnp.int32)
                total_tokens += int((~done).sum())
                done |= np.asarray(tok) == 1  # EOS
                if done.all():
                    break
            served += bs
        dt = time.perf_counter() - t0
        print(f"[serve] {cfg.name}: {served} requests, {total_tokens} new "
              f"tokens in {dt:.2f}s ({total_tokens / dt:.1f} tok/s)")


if __name__ == "__main__":
    main()
