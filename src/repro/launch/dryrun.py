import os
os.environ["XLA_FLAGS"] = (
    "--xla_force_host_platform_device_count=512 "
    # keep scan bodies faithful: the CPU backend's loop-invariant code
    # motion materialises per-iteration mask tables ("wide" arrays) that a
    # TPU compile would compute in-register — it distorts the HBM-traffic
    # roofline term and bloats compile memory.
    "--xla_disable_hlo_passes=while-loop-invariant-code-motion,"
    "while-loop-expensive-invariant-code-motion")
# NOTE: the two lines above MUST run before any other import (jax locks the
# device count on first init), hence no module docstring above them and no
# `from __future__` (which would have to come first).
_DOC = """Multi-pod dry-run: AOT lower + compile every (arch x shape x mesh) cell.

Proves the distribution config is coherent without hardware: a successful
``.lower().compile()`` on the 512-fake-device CPU backend means GSPMD found
a consistent sharding for every op, every collective is expressible, and
``memory_analysis()`` bounds per-device HBM.  ``cost_analysis()`` +
collective-bytes parsed from the optimized HLO feed §Roofline.

Usage:
  python -m repro.launch.dryrun --arch qwen3-0.6b --shape train_4k \
      --mesh single --out results/dryrun
  python -m repro.launch.dryrun --all --mesh both --out results/dryrun
(--all spawns one subprocess per cell so XLA state never accumulates.)
"""

import argparse
import json
import subprocess
import sys
import time

import jax

from repro import configs


def _mesh(kind: str):
    from repro.launch.mesh import make_production_mesh
    return make_production_mesh(multi_pod=(kind == "multi"))


def run_cell(arch: str, shape_name: str, mesh_kind: str,
             microbatches: int = 4, want_hlo: bool = False,
             overrides: dict | None = None,
             zero_serve_params: bool | None = None) -> dict:
    """Lower + compile one cell; returns the roofline-ready record."""
    from repro.launch import specs
    from repro.launch.mesh import use_mesh
    from repro.models.common import configure_activation_sharding
    from repro.roofline.collect import collect_compiled

    ok, why = configs.applicable(arch, shape_name)
    if not ok:
        return {"arch": arch, "shape": shape_name, "mesh": mesh_kind,
                "status": "skipped", "reason": why}

    shape = configs.SHAPES[shape_name]
    mesh = _mesh(mesh_kind)
    t0 = time.time()
    cfg = configs.get_config(arch)
    with use_mesh(mesh):
        batch_axes = tuple(a for a in ("pod", "data") if a in mesh.shape)
        heads = "model" if (cfg.n_heads and
                            cfg.n_heads % mesh.shape["model"] == 0) else None
        vocab = "model" if cfg.vocab % mesh.shape["model"] == 0 else None
        configure_activation_sharding(batch_axes, "model", heads, vocab)
        try:
            if shape.kind == "train":
                fn, args, in_sh, out_sh = specs.train_cell(
                    arch, shape_name, mesh, microbatches=microbatches,
                    overrides=overrides)
                jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                                 donate_argnums=(0, 1))
            else:
                kind = "prefill" if shape.kind == "prefill" else "decode"
                fn, args, in_sh, out_sh = specs.serve_cell(
                    arch, shape_name, mesh, kind, overrides=overrides,
                    zero_params=zero_serve_params)
                jitted = jax.jit(fn, in_shardings=in_sh, out_shardings=out_sh,
                                 donate_argnums=(2,))
            lowered = jitted.lower(*args)
            t_lower = time.time() - t0
            compiled = lowered.compile()
            t_compile = time.time() - t0 - t_lower
        finally:
            configure_activation_sharding(None, None, None, None)

    record = collect_compiled(compiled, lowered)
    record.update({
        "arch": arch, "shape": shape_name, "mesh": mesh_kind,
        "status": "ok", "kind": shape.kind,
        "seq_len": shape.seq_len, "global_batch": shape.global_batch,
        "n_devices": mesh.size, "microbatches": microbatches,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
    })
    if want_hlo:
        record["hlo_text"] = compiled.as_text()
    return record


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single",
                    choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--microbatches", type=int, default=4)
    ap.add_argument("--out", default="results/dryrun")
    ap.add_argument("--timeout", type=int, default=1800)
    ap.add_argument("--set", nargs="*", default=[],
                    help="ArchConfig overrides, e.g. ssm_chunk=128")
    ap.add_argument("--serve-sharding", default="auto",
                    choices=["auto", "zero", "replicated"])
    args = ap.parse_args()
    overrides = {}
    for kv in args.set:
        k, v = kv.split("=")
        overrides[k] = type(getattr(configs.get_config("qwen3-0.6b"), k))(
            eval(v) if v in ("True", "False") else v)             if not v.lstrip("-").isdigit() else int(v)
    os.makedirs(args.out, exist_ok=True)

    meshes = ["single", "multi"] if args.mesh == "both" else [args.mesh]

    if args.all:
        # one subprocess per cell: isolates XLA state + survives OOM/crash
        cells = [(a, s) for a, s, ok, _ in configs.cells(include_skipped=True)]
        failures = []
        for mesh_kind in meshes:
            for arch, shape in cells:
                tag = f"{arch}__{shape}__{mesh_kind}"
                out_file = os.path.join(args.out, tag + ".json")
                if os.path.exists(out_file):
                    print(f"[dryrun] {tag}: cached")
                    continue
                cmd = [sys.executable, "-m", "repro.launch.dryrun",
                       "--arch", arch, "--shape", shape, "--mesh", mesh_kind,
                       "--microbatches", str(args.microbatches),
                       "--serve-sharding", args.serve_sharding,
                       "--out", args.out] + \
                    (["--set"] + args.set if args.set else [])
                print(f"[dryrun] {tag} ...", flush=True)
                r = subprocess.run(cmd, capture_output=True, text=True,
                                   timeout=args.timeout)
                if r.returncode != 0:
                    failures.append(tag)
                    with open(os.path.join(args.out, tag + ".err"), "w") as f:
                        f.write(r.stdout[-4000:] + "\n" + r.stderr[-8000:])
                    print(f"[dryrun] {tag}: FAILED")
                else:
                    print(r.stdout.strip().splitlines()[-1]
                          if r.stdout.strip() else f"[dryrun] {tag}: ok")
        print(f"[dryrun] done; {len(failures)} failures: {failures}")
        return 1 if failures else 0

    assert args.arch and args.shape, "--arch/--shape or --all required"
    for mesh_kind in meshes:
        rec = run_cell(args.arch, args.shape, mesh_kind, args.microbatches,
                       overrides=overrides or None,
                       zero_serve_params={"auto": None, "zero": True,
                                          "replicated": False}[
                                              args.serve_sharding])
        tag = f"{args.arch}__{args.shape}__{mesh_kind}"
        with open(os.path.join(args.out, tag + ".json"), "w") as f:
            json.dump(rec, f, indent=1)
        if rec["status"] == "ok":
            print(f"[dryrun] {tag}: ok flops={rec['flops']:.3e} "
                  f"bytes/dev={rec['bytes_per_device']:.3e} "
                  f"coll_bytes={rec['collective_bytes']:.3e} "
                  f"compile={rec['compile_s']}s")
        else:
            print(f"[dryrun] {tag}: {rec['status']} ({rec.get('reason','')})")
    return 0


if __name__ == "__main__":
    sys.exit(main())
