"""Training driver: ``python -m repro.launch.train --arch qwen3-0.6b
--smoke --steps 200``.

Composes the whole stack: config -> model -> sharded train step (pjit) ->
synthetic data -> fault-tolerant loop (checkpoint/restart, NaN rollback,
straggler monitor).  On this CPU container use ``--smoke`` (reduced config,
host mesh); on a real fleet drop it and the production mesh applies.
"""
from __future__ import annotations

import argparse

import jax
import numpy as np

from repro import configs
from repro.data import SyntheticLM
from repro.launch.mesh import (make_host_mesh, make_production_mesh,
                               use_mesh)
from repro.models import get_model
from repro.models.common import configure_activation_sharding
from repro.optim import adamw, cosine_schedule, int8_compressed
from repro.runtime import make_train_step, sharding as shard_rules, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config + host mesh (CPU-runnable)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=128)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--microbatches", type=int, default=1)
    ap.add_argument("--lr", type=float, default=3e-3)
    ap.add_argument("--warmup", type=int, default=20)
    ap.add_argument("--ckpt-dir", default="checkpoints")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--compress-grads", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--model-axis", type=int, default=1)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--accel-target", default="hvx",
                    help="Covenant target name for the layer-compile report: "
                         "any repro.targets name, incl. derived variants "
                         "like 'dnnweaver@pe=32x32' ('none' disables it)")
    args = ap.parse_args()

    cfg = configs.get_config(args.arch, smoke=args.smoke)
    model = get_model(cfg)
    if args.accel_target != "none":
        # layer compilation goes through the unified driver (repro.compile):
        # per-GEMM accelerator cycles at the training token count, replayed
        # from the disk artifact store when REPRO_CACHE_DIR is set
        from repro.launch.layers import layer_report
        print(layer_report(cfg, tokens=args.global_batch * args.seq_len,
                           target=args.accel_target))
    mesh = make_host_mesh(args.model_axis) if args.smoke else \
        make_production_mesh(multi_pod=args.multi_pod)
    print(f"[train] {cfg.name} on mesh {dict(mesh.shape)}")

    opt = adamw(cosine_schedule(args.lr, args.warmup, args.steps))
    if args.compress_grads:
        opt = int8_compressed(opt)

    with use_mesh(mesh):
        params = model.init_params(jax.random.PRNGKey(args.seed))
        opt_state = opt.init(params)
        p_sh = shard_rules.shardings(params, mesh)
        o_sh = shard_rules.shardings(opt_state, mesh)
        params = jax.tree.map(jax.device_put, params, p_sh)
        opt_state = jax.tree.map(jax.device_put, opt_state, o_sh)

        step_fn = jax.jit(
            make_train_step(model.loss_fn, opt,
                            microbatches=args.microbatches,
                            grad_shardings=p_sh),
            in_shardings=(p_sh, o_sh, None), donate_argnums=(0, 1))

        data = SyntheticLM(
            vocab=cfg.vocab, seq_len=args.seq_len,
            global_batch=args.global_batch, seed=args.seed,
            extras={k: ((lambda b, s, fn=fn_d: fn(b, s)), dt)
                    for k, (fn_d, dt) in model.extra_inputs.items()})

        params, opt_state, report = train_loop(
            step_fn, params, opt_state, lambda s: data.batch(s),
            steps=args.steps, ckpt_dir=f"{args.ckpt_dir}/{cfg.name}",
            ckpt_every=args.ckpt_every)
    print(f"[train] done: {report.steps_run} steps, "
          f"loss {report.losses[0]:.3f} -> {report.losses[-1]:.3f}, "
          f"{report.rollbacks} rollbacks, "
          f"{len(report.slow_steps)} straggler events")


if __name__ == "__main__":
    main()
