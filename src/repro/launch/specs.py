"""Input specs + sharding assignments for every (arch x shape) dry-run cell.

``input_specs`` returns ShapeDtypeStruct stand-ins for every model input —
weak-type-correct, shardable, no device allocation.  ``cell_shardings``
maps params / optimizer state / batch / cache onto the mesh:

* params & optimizer moments: rule engine (runtime/sharding.py) — tensor
  axes over ``model``, ZeRO weight shard over ``data``;
* batch dims over ``(pod, data)``;
* KV caches: batch over data; sequence over ``model`` when kv_heads can't
  fill it, else kv-heads over ``model``; SSM states: heads over ``model``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import configs
from repro.data import make_batch_specs
from repro.models import get_model
from repro.optim import adamw
from repro.runtime import sharding as shard_rules


def _batch_axes(mesh: Mesh) -> tuple:
    return tuple(a for a in ("pod", "data") if a in mesh.shape)


def train_batch_specs(cfg, shape: configs.ShapeSpec, model):
    seq = shape.seq_len
    if cfg.family == "vlm":
        seq = shape.seq_len - cfg.vis_tokens  # image prefix fills the rest
    return make_batch_specs(cfg, shape.global_batch, seq,
                            extras=model.extra_inputs)


def serve_specs(cfg, shape: configs.ShapeSpec, model):
    """(prefill batch specs, decode token specs, cache specs)."""
    bs = shape.global_batch
    cache = jax.eval_shape(lambda: model.init_cache(bs, shape.seq_len))
    prefill_batch = {
        "tokens": jax.ShapeDtypeStruct((bs, shape.seq_len), jnp.int32)}
    if cfg.family == "vlm":
        prefill_batch["tokens"] = jax.ShapeDtypeStruct(
            (bs, shape.seq_len - cfg.vis_tokens), jnp.int32)
    for name, (shape_fn, dtype) in model.extra_inputs.items():
        prefill_batch[name] = jax.ShapeDtypeStruct(
            shape_fn(bs, shape.seq_len), dtype)
    tokens = jax.ShapeDtypeStruct((bs,), jnp.int32)
    return prefill_batch, tokens, cache


# ---------------------------------------------------------------------------
# cache sharding
# ---------------------------------------------------------------------------


def _divisible(dim: int, mesh: Mesh, axes) -> bool:
    if axes is None:
        return False
    size = int(np.prod([mesh.shape[a] for a in
                        ((axes,) if isinstance(axes, str) else axes)]))
    return dim >= size and dim % size == 0


def cache_spec_for(path: str, shape: tuple, cfg, mesh: Mesh) -> P:
    batch = _batch_axes(mesh)
    batch = batch if len(batch) != 1 else batch[0]

    def b_if(dim):  # batch axes if they divide, else replicate
        return batch if _divisible(dim, mesh, batch) else None

    if path.endswith("length"):
        return P(b_if(shape[0]))
    if "conv" in path:                       # (..., B, w-1, conv_ch)
        lead = len(shape) - 3
        return P(*([None] * lead), b_if(shape[-3]), None,
                 "model" if _divisible(shape[-1], mesh, "model") else None)
    if "ssm" in path:                        # (..., B, H, N, Pdim)
        lead = len(shape) - 4
        return P(*([None] * lead), b_if(shape[-4]),
                 "model" if _divisible(shape[-3], mesh, "model") else None,
                 None, None)
    if path.endswith("/k") or path.endswith("/v") or path in ("k", "v"):
        # (..., B, Hkv, S, hd): prefer kv-heads on model; else sequence
        lead = len(shape) - 4
        bdim, hdim, sdim = shape[-4], shape[-3], shape[-2]
        if _divisible(hdim, mesh, "model"):
            spec = (b_if(bdim), "model", None, None)
        elif _divisible(sdim, mesh, "model"):
            spec = (b_if(bdim), None, "model", None)
        else:
            spec = (b_if(bdim), None, None, None)
        return P(*([None] * lead), *spec)
    return P()


def cache_shardings(cache_tree, cfg, mesh: Mesh):
    def one(path, leaf):
        pstr = shard_rules._path_str(path)
        return NamedSharding(mesh, cache_spec_for(pstr, tuple(leaf.shape),
                                                  cfg, mesh))
    return jax.tree_util.tree_map_with_path(one, cache_tree)


# ---------------------------------------------------------------------------
# full cell assembly
# ---------------------------------------------------------------------------


def make_optimizer():
    return adamw(1e-4)


def cell_abstract(arch: str, shape_name: str, overrides: dict | None = None):
    """(cfg, model, shape); ``overrides`` are ArchConfig.replace kwargs
    (perf-iteration knobs: ssm_chunk, attn_block_kv, ...)."""
    cfg = configs.get_config(arch)
    if overrides:
        cfg = cfg.replace(**overrides)
    shape = configs.SHAPES[shape_name]
    model = get_model(cfg)
    return cfg, model, shape


def infer_param_shardings(p_sh):
    """Inference sharding: drop the ZeRO ``data`` axis from every param
    spec (weights replicated across data-parallel ranks).  Serving reads
    weights every step — re-gathering them per token is pure waste; the
    per-device HBM cost (params/|model|) is the explicit trade."""
    def fix(ns):
        spec = tuple(None if ax in ("data", ("data",)) else
                     (tuple(a for a in ax if a != "data") or None
                      if isinstance(ax, tuple) else ax)
                     for ax in tuple(ns.spec))
        return NamedSharding(ns.mesh, P(*spec))
    return jax.tree.map(fix, p_sh)


def train_cell(arch: str, shape_name: str, mesh: Mesh,
               microbatches: int = 4, overrides: dict | None = None):
    """Everything needed to lower a train step: (fn, args_sds, in_sh, out_sh,
    donate)."""
    from repro.runtime import make_train_step

    cfg, model, shape = cell_abstract(arch, shape_name, overrides)
    opt = make_optimizer()
    params_sds = jax.eval_shape(model.init_params, jax.random.key(0))
    opt_sds = jax.eval_shape(opt.init, params_sds)
    batch_sds = train_batch_specs(cfg, shape, model)

    p_sh = shard_rules.shardings(params_sds, mesh)
    o_sh = shard_rules.shardings(opt_sds, mesh)
    b_sh = shard_rules.batch_shardings(batch_sds, mesh)
    step = make_train_step(model.loss_fn, opt, microbatches=microbatches,
                           grad_shardings=p_sh)
    metrics_sh = {"loss": NamedSharding(mesh, P()),
                  "grad_norm": NamedSharding(mesh, P()),
                  "lr": NamedSharding(mesh, P())}
    return (step, (params_sds, opt_sds, batch_sds),
            (p_sh, o_sh, b_sh), (p_sh, o_sh, metrics_sh))


def serve_auto_policy(cfg, shape) -> bool:
    """True -> keep ZeRO (data-sharded) weights for serving.

    Measured policy (EXPERIMENTS.md §Perf B): replicated-over-data weights
    win for dense decode at batch >= 16 (kills per-token weight gathers);
    data-sharded weights win for MoE (expert tables dwarf the gather),
    for SSM decode (tiny recurrent state, weight reads dominate) and for
    tiny batches/models where the data axis is idle anyway."""
    return (cfg.family in ("moe", "ssm") or shape.global_batch < 16
            or cfg.d_model <= 1024)


def serve_cell(arch: str, shape_name: str, mesh: Mesh, kind: str,
               overrides: dict | None = None,
               zero_params: bool | None = None):
    """kind in {prefill, decode}: (fn, args_sds, in_sh, out_sh).
    ``zero_params``: True = ZeRO sharding, False = inference (replicated
    over data), None = measured auto policy."""
    cfg, model, shape = cell_abstract(arch, shape_name, overrides)
    if zero_params is None:
        zero_params = serve_auto_policy(cfg, shape)
    params_sds = jax.eval_shape(model.init_params, jax.random.key(0))
    p_sh = shard_rules.shardings(params_sds, mesh)
    if not zero_params:
        p_sh = infer_param_shardings(p_sh)
    pre_batch, tok_sds, cache_sds = serve_specs(cfg, shape, model)
    c_sh = cache_shardings(cache_sds, cfg, mesh)
    vocab_ax = "model" if _divisible(cfg.vocab, mesh, "model") else None
    batch_ax = _squash(_batch_axes(mesh)) \
        if _divisible(shape.global_batch, mesh, _batch_axes(mesh)) else None
    logits_sh = NamedSharding(mesh, P(batch_ax, vocab_ax))

    if kind == "prefill":
        b_sh = shard_rules.batch_shardings(pre_batch, mesh)

        def fn(params, batch, cache):
            return model.prefill(params, batch, cache)

        return fn, (params_sds, pre_batch, cache_sds), \
            (p_sh, b_sh, c_sh), (logits_sh, c_sh)

    tok_sh = NamedSharding(mesh, P(batch_ax))

    def fn(params, tokens, cache):
        return model.decode_step(params, tokens, cache)

    return fn, (params_sds, tok_sds, cache_sds), \
        (p_sh, tok_sh, c_sh), (logits_sh, c_sh)


def _squash(axes: tuple):
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


__all__ = ["cache_shardings", "cache_spec_for", "cell_abstract",
           "serve_cell", "serve_specs", "train_batch_specs", "train_cell"]
