"""Production mesh factory.

A FUNCTION (not module-level state) so importing this module never touches
jax device initialisation.  Single pod: 16x16 = 256 chips, axes
(data, model).  Multi-pod: 2x16x16 = 512 chips, axes (pod, data, model) —
``pod`` is a second data-parallel axis whose gradient all-reduce crosses
the DCI; nothing else communicates across pods.
"""
from __future__ import annotations

import jax


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes)


def make_host_mesh(model_axis: int = 1):
    """Whatever devices this host has, as (data, model) — for examples
    and tests on CPU."""
    n = len(jax.devices())
    assert n % model_axis == 0
    return jax.make_mesh((n // model_axis, model_axis), ("data", "model"))


def use_mesh(mesh):
    """Ambient-mesh context manager across jax versions: ``jax.set_mesh``
    (new), ``jax.sharding.use_mesh`` (mid), or the ``Mesh`` object itself
    (0.4.x, where Mesh is a context manager)."""
    if hasattr(jax, "set_mesh"):
        return jax.set_mesh(mesh)
    if hasattr(jax.sharding, "use_mesh"):
        return jax.sharding.use_mesh(mesh)
    return mesh


__all__ = ["make_host_mesh", "make_production_mesh", "use_mesh"]
