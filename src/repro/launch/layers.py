"""Covenant layer compilation for the serving/training stack.

The launch layer runs real models through jax/XLA; this module is the
bridge back to the paper's compiler: it maps an ``ArchConfig``'s per-block
GEMM workloads (QKV/out projections, FFN matmuls, LM head) onto Covenant
codelets and compiles them through the unified driver — ``repro.compile``
— so serving and training jobs get accelerator cycle analytics, schedule
search (``CompileOptions(search=...)``) and warm-start artifact-store
replay (``REPRO_CACHE_DIR``) on the exact shapes they are about to run.

This is the "remaining driver migrations" item from ROADMAP: nothing here
hand-stitches scheduler/codegen calls; every compile goes through the
driver's pipeline/cache/store seam.
"""
from __future__ import annotations

import dataclasses

import repro
from repro.core import library


@dataclasses.dataclass(frozen=True)
class LayerGemm:
    """One GEMM workload of an LM block: ``out[tokens, n] += x[tokens, k]
    @ w[k, n]``."""

    name: str
    tokens: int  # rows: batch (decode) or batch*seq (train/prefill)
    n: int
    k: int

    def build(self) -> "library.Codelet":
        return library.gemm(self.tokens, self.n, self.k, name=self.name)


def lm_layer_gemms(cfg, tokens: int, lm_head: bool = True) -> list[LayerGemm]:
    """The GEMM workloads of one transformer block of ``cfg`` (plus the LM
    head) at ``tokens`` rows.  Families without attention (pure SSM) just
    contribute their FFN/head GEMMs."""
    out: list[LayerGemm] = []
    d = cfg.d_model
    tag = cfg.name.replace(".", "_").replace("-", "_")
    if getattr(cfg, "n_heads", 0):
        qkv = (cfg.n_heads + 2 * max(cfg.n_kv_heads, 1)) * cfg.hd
        out.append(LayerGemm(f"{tag}_attn_qkv", tokens, qkv, d))
        out.append(LayerGemm(f"{tag}_attn_out", tokens, d,
                             cfg.n_heads * cfg.hd))
    if getattr(cfg, "d_ff", 0):
        out.append(LayerGemm(f"{tag}_ffn_in", tokens, cfg.d_ff, d))
        out.append(LayerGemm(f"{tag}_ffn_out", tokens, d, cfg.d_ff))
    if lm_head and getattr(cfg, "vocab", 0):
        out.append(LayerGemm(f"{tag}_lm_head", tokens, cfg.vocab, d))
    return out


def compile_layer_gemms(cfg, tokens: int, target: str = "hvx",
                        options: "repro.CompileOptions | None" = None,
                        parallel: int | None = None,
                        ) -> list[tuple[LayerGemm, "repro.CompiledArtifact"]]:
    """Compile every block GEMM of ``cfg`` through ``repro.compile_many``
    (shared content-addressed cache + optional disk store/search).

    ``target`` is any ``repro.targets`` name, including derived-variant
    names (``"dnnweaver@pe=32x32"``) — serving/training jobs can report
    cycles against a perturbed accelerator without code changes.

    ``parallel=N`` (with a disk store configured) fans cold compiles out
    across N worker processes; ``LayerGemm`` records serialise into sweep
    work units, so big-vocab heads and deep FFN stacks compile
    concurrently while results stream back through the shared store."""
    gemms = lm_layer_gemms(cfg, tokens)
    arts = repro.compile_many(gemms, target=target, options=options,
                              parallel=parallel)
    return list(zip(gemms, arts))


def variant_report(cfg, tokens: int, targets: "list[str]",
                   options: "repro.CompileOptions | None" = None,
                   parallel: int | None = None) -> str:
    """Per-GEMM cycles across several targets / architecture variants in
    one batched heterogeneous ``compile_many`` sweep — the design-space
    view of a serving config (``parallel=N`` shards it across worker
    processes over the shared artifact store)."""
    gemms = lm_layer_gemms(cfg, tokens)
    pairs = [(g, t) for t in targets for g in gemms]
    arts = repro.compile_many(pairs, options=options, parallel=parallel)
    width = max(len(g.name) for g in gemms)
    lines = [f"[covenant] {cfg.name} variants, tokens={tokens}"]
    header = "  " + " " * width + "".join(f" {t:>24s}" for t in targets)
    lines.append(header)
    for gi, g in enumerate(gemms):
        row = f"  {g.name:{width}s}"
        for ti in range(len(targets)):
            row += f" {arts[ti * len(gemms) + gi].cycles():24.0f}"
        lines.append(row)
    return "\n".join(lines)


def layer_report(cfg, tokens: int, target: str = "hvx",
                 options: "repro.CompileOptions | None" = None) -> str:
    """Human-readable per-GEMM cycle table + driver cache/store stats."""
    pairs = compile_layer_gemms(cfg, tokens, target, options)
    width = max(len(g.name) for g, _ in pairs)
    lines = [f"[covenant] {cfg.name} @ {target}, tokens={tokens}"]
    total = 0.0
    for g, art in pairs:
        cyc = art.cycles()
        total += cyc
        searched = ""
        if art.search is not None:
            searched = f"  search_gain=x{art.search.gain:.2f}"
        shape = f"{g.tokens}x{g.n}x{g.k}"
        lines.append(f"  {g.name:{width}s} {shape:16s} "
                     f"{cyc:14.0f} cyc{searched}")
    stats = repro.cache_stats()
    lines.append(f"  {'block total':{width}s} {'':16s} {total:14.0f} cyc  "
                 f"(cache hits={stats['hits']} misses={stats['misses']} "
                 f"store_hits={stats['store_hits']})")
    return "\n".join(lines)


__all__ = ["LayerGemm", "compile_layer_gemms", "layer_report",
           "lm_layer_gemms", "variant_report"]
