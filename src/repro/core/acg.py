"""Architecture Covenant Graph (ACG) — the paper's §2 abstraction.

An ACG is a directed graph whose vertices are *programmable* architecture
components and whose edges are programmable interconnect:

* ``MemoryNode``  — software-managed storage with ``data_width`` (bits served
  by one bank access), ``banks`` (parallel banks; ``data_width*banks`` is the
  addressable element) and ``depth`` (number of addressable elements).
* ``ComputeNode`` — functional unit described *only* through granularity-typed
  ``Capability`` signatures, e.g. ``(i32,64)=GEMM((i8,64),(i8,64,64),(i32,64))``.
* ``Edge``        — interconnect with a ``bandwidth`` attribute: bits moved by
  one transfer operation over that edge.

Non-programmable components (controllers, schedule memories) are deliberately
not represented — the ACG only carries what code generation needs.

Mnemonics (§2.1.4) are semantics-free binary code definitions: an opcode and
an ordered list of fixed-width fields (``ifield`` constants / ``efield``
enumerations).  They are attributes of the ACG, *not* of any execution model,
which is what lets the same code-generation machinery retarget accelerators
with systolic, dataflow or VLIW semantics.
"""
from __future__ import annotations

import dataclasses
import itertools
import math
from typing import Iterable, Mapping, Sequence

import networkx as nx

from .dtypes import Dtype, dt

# ---------------------------------------------------------------------------
# Capabilities
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class OperandSpec:
    """One operand of a capability: dtype + element geometry.

    ``shape`` is the element count per invocation; multi-dim shapes express
    things like DNNWeaver's systolic GEMM ``(i8,64,64)`` weight operand.
    """

    dtype: Dtype
    shape: tuple[int, ...]

    @property
    def elems(self) -> int:
        return math.prod(self.shape)

    @property
    def bits(self) -> int:
        return self.elems * self.dtype.bits

    def __str__(self) -> str:
        dims = ",".join(str(d) for d in self.shape)
        return f"({self.dtype},{dims})"


def ospec(dtype: str | Dtype, *shape: int) -> OperandSpec:
    d = dt(dtype) if isinstance(dtype, str) else dtype
    return OperandSpec(d, tuple(shape) if shape else (1,))


@dataclasses.dataclass(frozen=True)
class Capability:
    """A coarse-grained operation a compute node can perform (§2.1.3)."""

    name: str  # RELU/ADD/MUL/GEMM/... (Table 1)
    inputs: tuple[OperandSpec, ...]
    outputs: tuple[OperandSpec, ...]
    # optional cycle cost per invocation; defaults to 1 (systolic/SIMD issue).
    cycles: int = 1
    # matmul-family invocation geometry (m, n, k): output tile m*n, reduction
    # depth k consumed per invocation.  None for elementwise capabilities,
    # whose granularity is just ``out_elems`` lanes.
    geometry: tuple[int, int, int] | None = None

    @property
    def out_elems(self) -> int:
        """Granularity: output elements produced per invocation.

        This is what the compute-mapping pass maximises when several nodes
        support the same capability (§3.2: "selecting the ACG node capable of
        performing the most operations at a time").
        """
        return self.outputs[0].elems

    def matches(self, name: str, dtype: Dtype | None) -> bool:
        if self.name != name:
            return False
        if dtype is None:
            return True
        return any(o.dtype == dtype for o in self.outputs) or any(
            i.dtype == dtype for i in self.inputs
        )

    def __str__(self) -> str:
        outs = ",".join(str(o) for o in self.outputs)
        ins = ",".join(str(i) for i in self.inputs)
        return f"{outs}={self.name}({ins})"


def cap(name: str, outputs, inputs, cycles: int = 1,
        geometry: tuple[int, int, int] | None = None) -> Capability:
    """Terse capability builder: ``cap("ADD", ospec("i32",64), [ospec(...), ...])``."""
    if isinstance(outputs, OperandSpec):
        outputs = (outputs,)
    return Capability(name, tuple(inputs), tuple(outputs), cycles, geometry)


# ---------------------------------------------------------------------------
# Nodes and edges
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class MemoryNode:
    """Software-managed storage (§2.1.1)."""

    name: str
    data_width: int  # bits per bank access — alignment unit for Algorithm 1
    banks: int
    depth: int
    # True for off-chip / host-visible memory (the default operand home).
    offchip: bool = False

    @property
    def elem_bits(self) -> int:
        """Bits of one addressable element (all banks in parallel)."""
        return self.data_width * self.banks

    @property
    def capacity_bits(self) -> int:
        return self.elem_bits * self.depth

    @property
    def capacity_bytes(self) -> int:
        return self.capacity_bits // 8

    kind = "memory"


@dataclasses.dataclass(frozen=True)
class ComputeNode:
    """Programmable functional unit (§2.1.3)."""

    name: str
    capabilities: tuple[Capability, ...]
    # VLIW issue resource this node occupies (mnemonic packing, §4); nodes with
    # the same slot class contend for packet slots.
    slot: str | None = None

    def find(self, name: str, dtype: Dtype | None = None) -> list[Capability]:
        return [c for c in self.capabilities if c.matches(name, dtype)]

    kind = "compute"


@dataclasses.dataclass(frozen=True)
class Edge:
    """Directed programmable interconnect (§2.1.2)."""

    src: str
    dst: str
    bandwidth: int  # bits per transfer operation
    latency: int = 1  # cycles per transfer operation (cost model)

    def transfer_ops(self, bits: int) -> int:
        """Number of transfer operations needed to move ``bits`` over this edge."""
        return max(1, math.ceil(bits / self.bandwidth))


# ---------------------------------------------------------------------------
# Mnemonics (§2.1.4)
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Field:
    """One fixed-width field of a mnemonic.

    ``rw`` annotates read/write semantics of address-carrying fields; the
    mnemonic-packing pass (§4) uses it for dependency analysis.  ``None``
    means the field does not reference storage.
    """

    name: str
    bits: int
    enum: tuple[str, ...] | None = None  # efield when set, ifield otherwise
    rw: str | None = None  # "r" | "w" | None

    def encode(self, value) -> int:
        if self.enum is not None:
            idx = self.enum.index(value)
            return idx
        iv = int(value)
        if iv < 0 or iv >= (1 << self.bits):
            raise ValueError(f"field {self.name}: value {iv} does not fit in {self.bits} bits")
        return iv


def ifield(name: str, bits: int, rw: str | None = None) -> Field:
    return Field(name, bits, None, rw)


def efield(name: str, bits: int, values: Sequence[str], rw: str | None = None) -> Field:
    return Field(name, bits, tuple(values), rw)


@dataclasses.dataclass(frozen=True)
class MnemonicDef:
    """``mnemonic NAME(opcode) { field*, attr* }`` — Figure 6."""

    name: str
    opcode: int
    fields: tuple[Field, ...]
    # free-form attributes (e.g. which ACG node executes it) for analyses
    attrs: Mapping[str, object] = dataclasses.field(default_factory=dict)

    @property
    def bits(self) -> int:
        return 8 + sum(f.bits for f in self.fields)  # 8-bit opcode prefix

    def field(self, name: str) -> Field:
        for f in self.fields:
            if f.name == name:
                return f
        raise KeyError(f"mnemonic {self.name} has no field {name!r}")


@dataclasses.dataclass
class Mnemonic:
    """A mnemonic *instance*: a MnemonicDef with concrete field values."""

    mdef: MnemonicDef
    values: dict[str, object]
    # node occupied while executing (for packing + cycle model)
    node: str | None = None
    cycles: int = 1

    def encode(self) -> int:
        word = self.mdef.opcode & 0xFF
        for f in self.mdef.fields:
            word = (word << f.bits) | f.encode(self.values[f.name])
        return word

    def reads(self) -> set[tuple[str, object]]:
        return {
            (f.name, self.values[f.name]) for f in self.mdef.fields if f.rw == "r"
        }

    def writes(self) -> set[tuple[str, object]]:
        return {
            (f.name, self.values[f.name]) for f in self.mdef.fields if f.rw == "w"
        }

    def __str__(self) -> str:
        args = ", ".join(f"{f.name}={self.values[f.name]}" for f in self.mdef.fields)
        return f"{self.mdef.name} {args}"


# ---------------------------------------------------------------------------
# The graph itself
# ---------------------------------------------------------------------------


class ACG:
    """Architecture Covenant Graph: nodes + directed edges + mnemonic defs."""

    def __init__(self, name: str, issue_slots: int = 1, loop_overhead: int = 1):
        self.name = name
        # VLIW packet width; 1 means no packing is possible on this target.
        self.issue_slots = issue_slots
        # cycles of branch/bookkeeping per loop iteration (0 = hardware loops)
        self.loop_overhead = loop_overhead
        self.nodes: dict[str, MemoryNode | ComputeNode] = {}
        self.edges: list[Edge] = []
        self.mnemonics: dict[str, MnemonicDef] = {}
        # (compute_node, capability_name) -> ordered memory nodes each operand
        # must be staged in (inputs..., output).  Optional realism hint for
        # targets with dedicated per-operand buffers (DNNWeaver IBUF/WBUF/...).
        self.operand_ports: dict[tuple[str, str], tuple[str, ...]] = {}
        # BYOC-style pass hooks consumed by pipeline.Pipeline.with_acg_hooks:
        # ``pass_overrides`` replaces a named stage's body for this target;
        # ``extra_passes`` splices ("after:STAGE"|"before:STAGE", name, fn)
        # stages into the stock pipeline.  Empty on the stock targets.
        self.pass_overrides: dict[str, object] = {}
        self.extra_passes: list[tuple[str, str, object]] = []
        self._g = nx.DiGraph()

    # -- declarative covenant specs (core/spec.py) ---------------------------
    @classmethod
    def from_spec(cls, spec) -> "ACG":
        """Build an ACG from a declarative ``spec.ACGSpec`` (validated)."""
        from .spec import build_acg
        return build_acg(spec)

    def to_spec(self):
        """Snapshot this graph into its canonical ``spec.ACGSpec`` — the
        round-trip partner of ``from_spec`` and the basis of the ACG
        content fingerprint used by the compile cache and artifact store."""
        from .spec import spec_of
        return spec_of(self)

    # -- construction -------------------------------------------------------
    def add_memory(self, name: str, data_width: int, banks: int, depth: int,
                   offchip: bool = False) -> MemoryNode:
        node = MemoryNode(name, data_width, banks, depth, offchip)
        self._add_node(node)
        return node

    def add_compute(self, name: str, capabilities: Iterable[Capability],
                    slot: str | None = None) -> ComputeNode:
        node = ComputeNode(name, tuple(capabilities), slot)
        self._add_node(node)
        return node

    def _add_node(self, node) -> None:
        if node.name in self.nodes:
            raise ValueError(f"duplicate ACG node {node.name!r}")
        self.nodes[node.name] = node
        self._g.add_node(node.name)

    def connect(self, src: str, dst: str, bandwidth: int, latency: int = 1,
                bidir: bool = False) -> None:
        for s, d in ((src, dst), (dst, src)) if bidir else ((src, dst),):
            if s not in self.nodes or d not in self.nodes:
                raise KeyError(f"edge {s}->{d} references unknown node")
            e = Edge(s, d, bandwidth, latency)
            self.edges.append(e)
            self._g.add_edge(s, d, edge=e)

    def define_mnemonic(self, name: str, opcode: int, fields: Sequence[Field],
                        **attrs) -> MnemonicDef:
        mdef = MnemonicDef(name, opcode, tuple(fields), attrs)
        self.mnemonics[name] = mdef
        return mdef

    # -- queries used by the Covenant compiler ------------------------------
    def memory_nodes(self) -> list[MemoryNode]:
        return [n for n in self.nodes.values() if isinstance(n, MemoryNode)]

    def compute_nodes(self) -> list[ComputeNode]:
        return [n for n in self.nodes.values() if isinstance(n, ComputeNode)]

    def node(self, name: str):
        return self.nodes[name]

    def memory(self, name: str) -> MemoryNode:
        n = self.nodes[name]
        assert isinstance(n, MemoryNode), f"{name} is not a memory node"
        return n

    def compute(self, name: str) -> ComputeNode:
        n = self.nodes[name]
        assert isinstance(n, ComputeNode), f"{name} is not a compute node"
        return n

    def edge(self, src: str, dst: str) -> Edge:
        data = self._g.get_edge_data(src, dst)
        if data is None:
            raise KeyError(f"no ACG edge {src} -> {dst}")
        return data["edge"]

    def shortest_path(self, src: str, dst: str) -> list[str]:
        """Node path (inclusive) used by transfer insertion (§3.2)."""
        return nx.shortest_path(self._g, src, dst)

    def supporting_nodes(self, capability: str, dtype: Dtype | None = None
                         ) -> list[tuple[ComputeNode, Capability]]:
        """All (node, capability) pairs that can execute ``capability``,
        sorted by descending granularity — the compute-mapping order."""
        out = []
        for node in self.compute_nodes():
            for c in node.find(capability, dtype):
                out.append((node, c))
        out.sort(key=lambda nc: -nc[1].out_elems)
        return out

    def highest_memory(self) -> MemoryNode:
        """The operand home: the memory node with the longest shortest-path to
        the compute nodes (§3.1) — off-chip memory when present."""
        offchip = [m for m in self.memory_nodes() if m.offchip]
        if offchip:
            return offchip[0]
        best, best_d = None, -1
        for m in self.memory_nodes():
            dists = []
            for c in self.compute_nodes():
                try:
                    dists.append(len(self.shortest_path(m.name, c.name)) - 1)
                except nx.NetworkXNoPath:
                    continue
            if not dists:
                continue
            d = min(dists)
            if d > best_d:
                best, best_d = m, d
        if best is None:
            raise ValueError("ACG has no memory node reaching any compute node")
        return best

    def mem_neighbors(self, compute: str) -> list[MemoryNode]:
        """Memory nodes directly feeding a compute node."""
        return [
            self.nodes[p] for p in self._g.predecessors(compute)
            if isinstance(self.nodes[p], MemoryNode)
        ]

    # -- pretty -------------------------------------------------------------
    def describe(self) -> str:
        lines = [f"ACG {self.name} (issue_slots={self.issue_slots})"]
        for n in self.nodes.values():
            if isinstance(n, MemoryNode):
                lines.append(
                    f"  mem {n.name}: data_width={n.data_width} banks={n.banks} "
                    f"depth={n.depth} capacity={n.capacity_bytes}B"
                )
            else:
                lines.append(f"  cu  {n.name} (slot={n.slot}):")
                for c in n.capabilities:
                    lines.append(f"      {c}")
        for e in self.edges:
            lines.append(f"  edge {e.src} -> {e.dst} bw={e.bandwidth}b")
        return "\n".join(lines)


__all__ = [
    "ACG", "Capability", "ComputeNode", "Edge", "Field", "MemoryNode",
    "Mnemonic", "MnemonicDef", "OperandSpec", "cap", "dt", "efield",
    "ifield", "ospec",
]
