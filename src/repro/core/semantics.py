"""Numpy semantics for ACG capabilities.

The compiler treats capabilities/mnemonics as semantics-free (§2.1.4); the
*simulator* — like the vendor cycle-accurate simulators the paper measures
with — is where semantics live.  Integer unary nonlinearities (SIGMOID/TANH
on i32) are computed in float and rounded, standing in for the fixed-point
units real accelerators ship.
"""
from __future__ import annotations

import numpy as np

_BINARY = {
    "ADD": np.add,
    "SUB": np.subtract,
    "MUL": np.multiply,
    "MAX": np.maximum,
    "MIN": np.minimum,
}


def _div(a, b):
    if np.issubdtype(np.asarray(a).dtype, np.integer):
        return np.where(b == 0, 0, np.floor_divide(a, np.where(b == 0, 1, b)))
    return np.divide(a, np.where(b == 0, 1, b))


def _unary(name: str, x):
    xf = x.astype(np.float64)
    if name == "RELU":
        r = np.maximum(xf, 0)
    elif name == "SIGMOID":
        r = 1.0 / (1.0 + np.exp(-xf))
    elif name == "TANH":
        r = np.tanh(xf)
    else:
        raise KeyError(name)
    if np.issubdtype(x.dtype, np.integer):
        return np.rint(r).astype(x.dtype)
    return r.astype(x.dtype)


def apply_elementwise(name: str, out_dtype, ins: list[np.ndarray]) -> np.ndarray:
    if name in _BINARY:
        return _BINARY[name](ins[0].astype(out_dtype), ins[1].astype(out_dtype))
    if name == "DIV":
        return _div(ins[0].astype(out_dtype), ins[1].astype(out_dtype))
    return _unary(name, ins[0]).astype(out_dtype)


def apply_mac(out_dtype, a: np.ndarray, b: np.ndarray, acc: np.ndarray,
              labels: tuple[str, str, str]) -> np.ndarray:
    """MAC/GEMM family: ``acc + einsum(a, b)`` with per-operand dim labels
    drawn from {m,n,k} (extent-1 dims squeezed by the caller)."""
    la, lb, lc = labels
    prod = np.einsum(f"{la},{lb}->{lc}",
                     a.astype(np.int64) if np.issubdtype(np.dtype(out_dtype), np.integer)
                     else a.astype(np.float64),
                     b.astype(np.int64) if np.issubdtype(np.dtype(out_dtype), np.integer)
                     else b.astype(np.float64))
    return (acc.astype(prod.dtype) + prod).astype(out_dtype)


MATMUL_FAMILY = ("MAC", "GEMM", "MVMUL", "MMUL")
ELEMENTWISE = ("ADD", "SUB", "MUL", "DIV", "MAX", "MIN", "RELU", "SIGMOID", "TANH")

__all__ = ["ELEMENTWISE", "MATMUL_FAMILY", "apply_elementwise", "apply_mac"]
