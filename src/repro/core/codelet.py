"""Codelets — the paper's §3 compute-kernel abstraction.

A Codelet declares parametric-shaped *surrogate variables* (``inp`` / ``out``
/ ``param``; ``local`` surrogates appear during compilation) and a body of
``loop`` / ``compute`` / ``transfer`` operations.  Codelets start
architecture-agnostic (``dtype``/``loc`` = None) and are *gradually
transformed* by the Covenant pipeline: layer mapping binds params and dtypes
(Fig 7b), compute mapping assigns ACG compute nodes, tiling splits loops, and
transfer insertion materialises data movement (Fig 8c).

Index arithmetic is affine over loop variables (``a[mo+mi, ko+ki]``), which is
sufficient for the paper's benchmark set (GEMM / CONV / elementwise / MLP
layers) and keeps footprint analysis exact.
"""
from __future__ import annotations

import copy
import dataclasses
import math
from typing import Callable, Iterator, Sequence

from .dtypes import Dtype, dt

# ---------------------------------------------------------------------------
# Affine index expressions
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class Aff:
    """Affine expression: sum(coeff * loop_var) + const."""

    terms: tuple[tuple[str, int], ...] = ()
    const: int = 0

    @staticmethod
    def of(x: "Aff | str | int") -> "Aff":
        if isinstance(x, Aff):
            return x
        if isinstance(x, str):
            return Aff(((x, 1),), 0)
        return Aff((), int(x))

    def __add__(self, other) -> "Aff":
        o = Aff.of(other)
        d = dict(self.terms)
        for v, c in o.terms:
            d[v] = d.get(v, 0) + c
        return Aff(tuple(sorted((v, c) for v, c in d.items() if c)), self.const + o.const)

    __radd__ = __add__

    def __mul__(self, k: int) -> "Aff":
        return Aff(tuple((v, c * k) for v, c in self.terms), self.const * k)

    __rmul__ = __mul__

    def vars(self) -> set[str]:
        return {v for v, _ in self.terms}

    def eval(self, env: dict[str, int]) -> int:
        return self.const + sum(c * env[v] for v, c in self.terms)

    def __str__(self) -> str:
        parts = [f"{v}" if c == 1 else f"{c}*{v}" for v, c in self.terms]
        if self.const or not parts:
            parts.append(str(self.const))
        return "+".join(parts)


def v(name: str) -> Aff:
    return Aff.of(name)


# ---------------------------------------------------------------------------
# Surrogates (§3.1)
# ---------------------------------------------------------------------------

KINDS = ("inp", "out", "param", "local", "const")


@dataclasses.dataclass
class Surrogate:
    """A single-location variable carrying shape, dtype and ACG location."""

    name: str
    kind: str
    shape: tuple[int, ...] | None = None
    dtype: Dtype | None = None
    loc: str | None = None
    value: object = None  # param value / const fill value

    def __post_init__(self):
        assert self.kind in KINDS, self.kind

    @property
    def elems(self) -> int:
        assert self.shape is not None, f"surrogate {self.name} has unbound shape"
        return math.prod(self.shape)

    @property
    def bits(self) -> int:
        assert self.dtype is not None, f"surrogate {self.name} has unbound dtype"
        return self.elems * self.dtype.bits

    def __str__(self) -> str:
        shp = "?" if self.shape is None else list(self.shape)
        return (f"{self.name}={self.kind}({shp},{self.dtype or 'null'},"
                f"{self.loc or 'null'})")


@dataclasses.dataclass(frozen=True)
class Ref:
    """Reference to a surrogate with affine per-dim offsets.

    ``sizes`` (when set) is the extent read/written per dim starting at the
    offset — transfers carry it explicitly (paper: "the transfer size in
    number of source elements in each dimension").
    """

    var: str
    idx: tuple[Aff, ...] = ()
    sizes: tuple[int, ...] | None = None

    def __str__(self) -> str:
        s = self.var
        if self.idx:
            s += "[" + ",".join(str(i) for i in self.idx) + "]"
        return s


def ref(var: str | Surrogate, *idx, sizes: Sequence[int] | None = None) -> Ref:
    name = var.name if isinstance(var, Surrogate) else var
    return Ref(name, tuple(Aff.of(i) for i in idx),
               tuple(sizes) if sizes is not None else None)


# ---------------------------------------------------------------------------
# Operations (§3.2)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Loop:
    var: str
    start: int
    stop: int
    stride: int = 1
    body: list = dataclasses.field(default_factory=list)
    # marks loops produced by tiling splits (outer) and their inner twins
    role: str = "orig"  # "orig" | "tile" | "intra" | "unrolled"

    @property
    def trips(self) -> int:
        return max(0, math.ceil((self.stop - self.start) / self.stride))

    def __str__(self) -> str:
        return f"loop {self.var}({self.start},{self.stop},{self.stride})"


@dataclasses.dataclass
class Compute:
    capability: str
    out: Ref
    ins: tuple[Ref, ...]
    loc: str | None = None  # ACG compute node once mapped
    # loop-var role groups used by vectorization/tiling to align codelet loops
    # with capability geometry:  {"m": [...], "n": [...], "k": [...]} for the
    # matmul family, {"n": [...]} for elementwise lanes.
    roles: dict = dataclasses.field(default_factory=dict)
    # capability object chosen by compute mapping (granularity/geometry info)
    cap_obj: object = None
    dtype: object = None  # output Dtype, bound at layer mapping

    def __str__(self) -> str:
        ins = ",".join(str(i) for i in self.ins)
        return f'{self.out}=compute({self.loc or "null"},"{self.capability}",{ins})'


@dataclasses.dataclass
class Transfer:
    """Three paper forms:

    * ``dst_loc`` set, ``alloc`` set      — move src tile to a memory node,
      creating a new ``local`` surrogate (``x1=transfer(x[n],"MEM2",[2])``).
    * ``src`` is a const Ref (var=="") + ``alloc``  — allocate zero-filled
      local (``c1=transfer(i16(0),"MEM2",[2])``).
    * ``dst`` set                          — overwrite existing surrogate
      (``transfer(c1, c[n], [2])``).
    """

    src: Ref
    sizes: tuple[int, ...]
    dst_loc: str | None = None
    dst: Ref | None = None
    alloc: str | None = None  # name of the local surrogate created
    fill: object = None       # const fill value for allocation form

    def __str__(self) -> str:
        if self.dst_loc is not None:
            src = f"{self.src}" if self.src.var else f"fill({self.fill})"
            return (f'{self.alloc}=transfer({src},"{self.dst_loc}",'
                    f"{list(self.sizes)})")
        return f"transfer({self.src},{self.dst},{list(self.sizes)})"


Op = Loop | Compute | Transfer


# ---------------------------------------------------------------------------
# Codelet container
# ---------------------------------------------------------------------------


class Codelet:
    def __init__(self, name: str):
        self.name = name
        self.surrogates: dict[str, Surrogate] = {}
        self.body: list[Op] = []
        # Filled by the Covenant pipeline:
        self.tiling: dict[str, int] = {}       # loop var -> tile size
        self.schedule_notes: list[str] = []    # human-readable pass log
        # numpy reference oracle: {inp_name: arr} -> {out_name: arr}
        self.oracle = None

    # -- declaration API (used by the layer library) -------------------------
    def param(self, name: str, value=None) -> Surrogate:
        return self._add(Surrogate(name, "param", value=value))

    def inp(self, name: str, shape=None, dtype=None, loc=None) -> Surrogate:
        return self._add(Surrogate(name, "inp", _shp(shape), _dt(dtype), loc))

    def out(self, name: str, shape=None, dtype=None, loc=None) -> Surrogate:
        return self._add(Surrogate(name, "out", _shp(shape), _dt(dtype), loc))

    def local(self, name: str, shape, dtype, loc) -> Surrogate:
        return self._add(Surrogate(name, "local", _shp(shape), _dt(dtype), loc))

    def _add(self, s: Surrogate) -> Surrogate:
        if s.name in self.surrogates:
            raise ValueError(f"duplicate surrogate {s.name!r} in codelet {self.name}")
        self.surrogates[s.name] = s
        return s

    def fresh_name(self, base: str) -> str:
        i = 1
        while f"{base}{i}" in self.surrogates:
            i += 1
        return f"{base}{i}"

    # -- traversal -----------------------------------------------------------
    def walk(self) -> Iterator[tuple[list[Loop], Op]]:
        """Yield (enclosing_loops, op) in program order."""

        def rec(ops, stack):
            for op in ops:
                yield stack, op
                if isinstance(op, Loop):
                    yield from rec(op.body, stack + [op])

        yield from rec(self.body, [])

    def loops(self) -> list[Loop]:
        return [op for _, op in self.walk() if isinstance(op, Loop)]

    def computes(self) -> list[tuple[list[Loop], Compute]]:
        return [(ls, op) for ls, op in self.walk() if isinstance(op, Compute)]

    def transfers(self) -> list[tuple[list[Loop], Transfer]]:
        return [(ls, op) for ls, op in self.walk() if isinstance(op, Transfer)]

    def loop(self, var: str) -> Loop:
        for l in self.loops():
            if l.var == var:
                return l
        raise KeyError(f"no loop {var!r} in codelet {self.name}")

    def clone(self) -> "Codelet":
        return copy.deepcopy(self)

    def note(self, msg: str) -> None:
        self.schedule_notes.append(msg)

    # -- pretty printer (paper syntax) ---------------------------------------
    def __str__(self) -> str:
        lines = [f"cdlt {self.name} {{"]
        for s in self.surrogates.values():
            if s.kind in ("inp", "out", "param"):
                lines.append(f"  {s};")

        def emit(ops, ind):
            for op in ops:
                if isinstance(op, Loop):
                    lines.append(f"{' ' * ind}{op} {{")
                    emit(op.body, ind + 2)
                    lines.append(f"{' ' * ind}}}")
                else:
                    lines.append(f"{' ' * ind}{op};")

        emit(self.body, 2)
        lines.append("}")
        return "\n".join(lines)


def _shp(shape):
    return tuple(int(x) for x in shape) if shape is not None else None


def _dt(d):
    if d is None or isinstance(d, Dtype):
        return d
    return dt(d)


# ---------------------------------------------------------------------------
# Footprint analysis — how many elements of a surrogate one iteration of a
# given loop level touches; exact for affine indices with unit coefficients.
# ---------------------------------------------------------------------------


def ref_footprint(ref: Ref, surrogate: Surrogate, extents: dict[str, int]) -> tuple[int, ...]:
    """Per-dim element extent touched by ``ref`` when each loop var in
    ``extents`` ranges over [0, extent) and all other vars are fixed.

    ``ref.sizes`` (granularity of the access itself) multiplies in.
    """
    assert surrogate.shape is not None
    dims = []
    for d, ix in enumerate(ref.idx):
        span = 1
        for var, coeff in ix.terms:
            if var in extents:
                span += abs(coeff) * (extents[var] - 1)
        base = ref.sizes[d] if ref.sizes else 1
        dims.append(min(surrogate.shape[d], span - 1 + base))
    if not ref.idx:  # whole-surrogate reference
        return surrogate.shape
    return tuple(dims)


__all__ = [
    "Aff", "Codelet", "Compute", "Loop", "Op", "Ref", "Surrogate",
    "Transfer", "ref", "ref_footprint", "v",
]
