"""Disk-backed, size-bounded artifact store (the ISA-Mapper measurement-
database pattern, keyed like the in-process compile cache).

One JSON file per content-addressed key.  An entry does NOT pickle the
scheduled codelet — it serialises the *schedule decisions* (tiling +
unroll factor + pack), the analytic cost report(s), the pass notes and the
search digest.  A warm hit therefore restores a ``CompiledArtifact`` whose
analytics (``cycles()`` / ``report()``) work with **zero pipeline stage
executions**; the scheduled codelet and mnemonic program are rebuilt
lazily — only if ``.program`` / ``.run()`` is actually touched — by
replaying the pipeline with the stored decisions injected as pass inputs
(no tiling enumeration, no search re-run).

Robustness contract (tests/test_store.py):
* corrupt / truncated / wrong-format entries read as a miss, the bad file
  is deleted, and the caller recompiles cleanly;
* the store is size-bounded: writes evict least-recently-used entries
  (mtime order; loads bump recency) until under ``max_bytes``;
* ``clear()`` (surfaced as ``repro.clear_cache(disk=True)``) empties it.

Activate per-compile with ``CompileOptions(store=ArtifactStore(dir))`` (or
``store="dir"``), or process-wide with the ``REPRO_CACHE_DIR`` environment
variable — that is what makes multi-process sweeps replay warm.

Multi-writer contract (``core/sweep.py`` coordinates fleets of worker
processes over one store):

* a single put is atomic (tmp + ``os.replace``) and is never evicted by
  the writing process itself;
* LRU eviction is serialised by a store-wide ``FileLock`` and never
  touches a *foreign* entry younger than ``FRESH_GRACE`` seconds, so two
  concurrently-evicting processes cannot delete each other's fresh puts;
* sweep workers claim work units through per-entry claim files
  (``claim()`` / ``release_claim()``) with a stale-claim timeout, so a
  crashed worker's units are reclaimed instead of lost;
* every compile a sweep performs is recorded in a monotonic, append-only
  ``SweepJournal`` (one JSON line per event, sequence numbers issued
  under the lock) — CI asserts "each work unit compiled exactly once"
  straight off the journal;
* ``gc()`` reclaims by age and size and reaps orphaned tmp/lock/claim
  files.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time as _time

FORMAT = 1
ENV_DIR = "REPRO_CACHE_DIR"
ENV_MAX_MB = "REPRO_CACHE_MAX_MB"
_SUFFIX = ".json"
# eviction never deletes another process's entry younger than this (s):
# between a foreign put and that process's first warm read there must be
# no window in which our own LRU scan can reap it
FRESH_GRACE = 30.0
_SWEEP_PREFIX = "sweep-"


def compiler_signature() -> str:
    """Digest of the stock compiler's source (pipeline stages, scheduler,
    passes, cost model, codegen).  Stamped into every store entry and
    checked on load, so a persistent REPRO_CACHE_DIR can never serve
    schedules or cycle counts produced by a *different* compiler — the
    content-addressed key only covers inputs, not the compiler itself."""
    global _SIGNATURE
    if _SIGNATURE is None:
        import hashlib
        import inspect

        from . import (codegen, cost, covenant, driver, passes, pipeline,
                       scheduler, search, spec)
        h = hashlib.sha256()
        for mod in (pipeline, scheduler, passes, cost, codegen, search,
                    driver, covenant, spec):
            try:
                h.update(inspect.getsource(mod).encode())
            except (OSError, TypeError):
                h.update(mod.__name__.encode())
        _SIGNATURE = h.hexdigest()[:16]
    return _SIGNATURE


_SIGNATURE: str | None = None


def _break_stale(path: str) -> bool:
    """Remove a stale lock/claim file *atomically claimed for removal*:
    rename-to-unique first, so of two breakers exactly one wins and
    neither can ever delete the file a third process just re-created
    under the original name (the stat-then-remove TOCTOU)."""
    tomb = f"{path}.stale-{os.getpid()}-{_time.monotonic_ns()}"
    try:
        os.rename(path, tomb)
    except OSError:
        return False  # someone else broke (or released) it first
    try:
        os.remove(tomb)
    except OSError:
        pass
    return True


class FileLock:
    """Cross-process advisory lock: an ``O_CREAT|O_EXCL`` lock file.

    A holder that dies leaves the file behind; any later acquirer breaks
    the lock once it is older than ``stale_timeout`` seconds — liveness
    over strictness, the right trade for a measurement cache (the guarded
    operations are idempotent or re-checkable).  Use as a context manager
    (raises ``TimeoutError``) or via ``acquire(timeout=0)`` for a
    non-blocking attempt.
    """

    def __init__(self, path: str, stale_timeout: float = 60.0):
        self.path = path
        self.stale_timeout = stale_timeout
        self._held = False

    def acquire(self, timeout: float = 10.0) -> bool:
        deadline = _time.monotonic() + timeout
        while True:
            try:
                fd = os.open(self.path,
                             os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                try:
                    age = _time.time() - os.stat(self.path).st_mtime
                except OSError:
                    continue  # holder released between open and stat: retry
                if age > self.stale_timeout:
                    _break_stale(self.path)  # losers just retry O_EXCL
                    continue
                if _time.monotonic() >= deadline:
                    return False
                _time.sleep(0.01)
                continue
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                f.write(json.dumps({"pid": os.getpid(),
                                    "time": _time.time()}))
            self._held = True
            return True

    def release(self) -> None:
        if self._held:
            self._held = False
            try:
                os.remove(self.path)
            except OSError:
                pass

    def __enter__(self) -> "FileLock":
        if not self.acquire():
            raise TimeoutError(f"could not acquire lock {self.path!r}")
        return self

    def __exit__(self, *exc) -> None:
        self.release()


class SweepJournal:
    """Monotonic, append-only event log of one sweep over a store.

    One JSON object per line in ``<root>/sweep-<id>/journal.jsonl``; each
    ``append`` is issued a strictly increasing ``seq`` under a
    ``FileLock``, so events from any number of worker processes totally
    order, and "each work unit compiled exactly once" is a pure journal
    query (``compile_counts``).  The journal survives warm re-runs of the
    same sweep id — a warm run that recompiles nothing appends only
    ``store_hit`` events, which is exactly what CI asserts.
    """

    def __init__(self, store: "ArtifactStore", sweep_id: str):
        self.store = store
        self.sweep_id = sweep_id
        self.dir = store.sweep_dir(sweep_id)
        self.path = os.path.join(self.dir, "journal.jsonl")
        self._seq_path = os.path.join(self.dir, "journal.seq")
        # the lock is held for one tiny read+append: a holder that lives
        # 10s is dead, and the 30s acquire window below always outlasts
        # the stale threshold, so a crashed holder can delay appends but
        # never wedge the fleet
        self._lock = FileLock(os.path.join(self.dir, "journal.lock"),
                              stale_timeout=10.0)

    def append(self, record: dict) -> int:
        """Write ``record`` (plus ``seq``/``time``/``sweep``) as one line;
        returns the issued sequence number."""
        if not self._lock.acquire(timeout=30.0):
            raise TimeoutError(
                f"could not acquire journal lock {self._lock.path!r}")
        try:
            try:
                with open(self._seq_path, "r", encoding="utf-8") as f:
                    seq = int(f.read().strip() or 0)
            except (OSError, ValueError):
                seq = 0
            seq += 1
            line = json.dumps(dict(record, seq=seq, sweep=self.sweep_id,
                                   time=_time.time()))
            with open(self.path, "a", encoding="utf-8") as f:
                f.write(line + "\n")
                f.flush()
                os.fsync(f.fileno())
            tmp = f"{self._seq_path}.{os.getpid()}.tmp"
            with open(tmp, "w", encoding="utf-8") as f:
                f.write(str(seq))
            os.replace(tmp, self._seq_path)
        finally:
            self._lock.release()
        return seq

    def read(self) -> list[dict]:
        """All events, in seq order; unreadable lines (a writer died mid-
        line) are skipped."""
        out = []
        try:
            with open(self.path, "r", encoding="utf-8") as f:
                for line in f:
                    try:
                        out.append(json.loads(line))
                    except ValueError:
                        continue
        except FileNotFoundError:
            return []
        out.sort(key=lambda r: r.get("seq", 0))
        return out

    def compile_counts(self) -> dict:
        """{key: number of 'compiled' events} — the exactly-once check."""
        counts: dict[str, int] = {}
        for rec in self.read():
            if rec.get("event") == "compiled":
                k = rec.get("key", "?")
                counts[k] = counts.get(k, 0) + 1
        return counts


class ArtifactStore:
    """Content-addressed key -> schedule-decision entry, on disk."""

    def __init__(self, root: str, max_bytes: int | None = None):
        self.root = os.path.abspath(os.path.expanduser(os.fspath(root)))
        if max_bytes is None:
            max_bytes = int(float(os.environ.get(ENV_MAX_MB, 256)) * 2 ** 20)
        self.max_bytes = max_bytes
        os.makedirs(self.root, exist_ok=True)
        self.stats = {"hits": 0, "misses": 0, "puts": 0, "evictions": 0,
                      "corrupt": 0, "stale": 0, "claims": 0, "reclaims": 0,
                      "claim_losses": 0}
        # entry paths THIS process wrote: eviction may reap our own fresh
        # entries (the size bound is ours to keep) but never a foreign
        # entry younger than FRESH_GRACE — see the multi-writer contract
        self._own: set[str] = set()
        # running size estimate: puts add to it, the (O(entries)) eviction
        # scan only runs once it crosses max_bytes, then re-measures
        self._approx_bytes = self.size_bytes()

    # -- paths ---------------------------------------------------------------
    def _path(self, key: str) -> str:
        assert key and all(c in "0123456789abcdef" for c in key), key
        return os.path.join(self.root, key + _SUFFIX)

    def _entries(self) -> list[str]:
        return self._listdir(_SUFFIX)

    def _tmp_files(self) -> list[str]:
        return self._listdir(".tmp")

    def _listdir(self, suffix: str) -> list[str]:
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return []
        return [os.path.join(self.root, n) for n in names
                if n.endswith(suffix)]

    # -- core ops ------------------------------------------------------------
    def load(self, key: str) -> dict | None:
        """The stored entry for ``key``, or None (miss).  Anything
        unreadable — truncated JSON, foreign schema, key mismatch — is
        treated as a miss and the offending file is removed."""
        path = self._path(key)
        try:
            with open(path, "r", encoding="utf-8") as f:
                entry = json.load(f)
            if not isinstance(entry, dict) or entry.get("format") != FORMAT \
                    or entry.get("key") != key or "reports" not in entry:
                raise ValueError("foreign or incomplete entry")
        except FileNotFoundError:
            self.stats["misses"] += 1
            return None
        except Exception:
            self.stats["corrupt"] += 1
            self.stats["misses"] += 1
            try:
                os.remove(path)
            except OSError:
                pass
            return None
        if entry.get("compiler") != compiler_signature():
            # produced by a different compiler version: the schedule and
            # cycle counts may no longer be what this compiler would emit
            self.stats["stale"] += 1
            self.stats["misses"] += 1
            try:
                os.remove(path)
            except OSError:
                pass
            return None
        try:
            os.utime(path, None)  # bump LRU recency
        except OSError:
            pass
        self.stats["hits"] += 1
        return entry

    def put(self, key: str, entry: dict) -> None:
        entry = dict(entry, format=FORMAT, key=key,
                     compiler=compiler_signature())
        path = self._path(key)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(entry, f)
            os.replace(tmp, path)  # atomic vs concurrent readers
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise
        self.stats["puts"] += 1
        self._own.add(path)
        try:
            self._approx_bytes += os.stat(path).st_size
        except OSError:
            pass
        if self._approx_bytes > self.max_bytes:
            self._evict(keep=path)

    def invalidate(self, key: str) -> None:
        """Forget an entry that loaded but could not be restored: delete
        the file and reclassify the load as a corrupt miss."""
        try:
            os.remove(self._path(key))
        except OSError:
            pass
        self.stats["hits"] -= 1
        self.stats["misses"] += 1
        self.stats["corrupt"] += 1

    def _evict_lock(self) -> FileLock:
        return FileLock(os.path.join(self.root, ".evict.lock"))

    def _evict(self, keep: str | None = None,
               max_bytes: int | None = None) -> None:
        """Drop least-recently-used entries until under ``max_bytes``;
        ``keep`` (the just-written path) is never a victim, even under
        mtime ties on coarse-timestamp filesystems, so a put always
        sticks.  Also reaps stale ``.tmp`` leftovers of interrupted puts —
        they are invisible to loads, so without this they would
        accumulate unbounded.

        Concurrency: the scan runs under a non-blocking store-wide lock —
        if another process is already evicting, we simply skip (the bound
        is approximate; the next put retries) — and *foreign* entries
        younger than ``FRESH_GRACE`` are never victims, so two processes
        evicting around the same time cannot reap each other's fresh
        puts before their writers ever read them back."""
        lock = self._evict_lock()
        if not lock.acquire(timeout=0):
            return
        try:
            budget = self.max_bytes if max_bytes is None else max_bytes
            now = _time.time()
            for p in self._tmp_files():
                try:
                    if now - os.stat(p).st_mtime > 600:
                        os.remove(p)
                except OSError:
                    pass
            files = []
            for p in self._entries():
                try:
                    st = os.stat(p)
                except OSError:
                    continue
                files.append((st.st_mtime, st.st_size, p))
            files.sort()
            total = sum(sz for _, sz, _ in files)
            if keep is None and files:
                keep = files[-1][2]  # protect the most recent entry
            victims = [f for f in files if f[2] != keep
                       and (f[2] in self._own
                            or now - f[0] > FRESH_GRACE)]
            while victims and total > budget:
                _, sz, victim = victims.pop(0)
                try:
                    os.remove(victim)
                except OSError:
                    continue
                self._own.discard(victim)
                total -= sz
                self.stats["evictions"] += 1
            self._approx_bytes = total
        finally:
            lock.release()

    def peek(self, key: str) -> dict | None:
        """Read an entry without touching stats, recency or the file
        itself — the sweep coordinator's dedup probe.  Any unreadable or
        foreign entry is simply ``None`` (the eventual ``load`` will
        classify and clean it)."""
        try:
            with open(self._path(key), "r", encoding="utf-8") as f:
                entry = json.load(f)
        except (OSError, ValueError):
            return None
        if not isinstance(entry, dict) or entry.get("format") != FORMAT \
                or entry.get("key") != key or "reports" not in entry \
                or entry.get("compiler") != compiler_signature():
            return None
        return entry

    def clear(self) -> None:
        import shutil
        for p in self._entries() + self._tmp_files():
            try:
                os.remove(p)
            except OSError:
                pass
        for d in self.sweep_dirs():
            shutil.rmtree(d, ignore_errors=True)
        shutil.rmtree(os.path.join(self.root, "pins"), ignore_errors=True)
        self._approx_bytes = 0

    # -- sweep coordination (claims + journals) ------------------------------
    def sweep_dir(self, sweep_id: str, create: bool = True) -> str:
        """Scratch directory of one sweep (claims, journal) under the
        store root — shared state travels with the measurement database."""
        assert sweep_id and "/" not in sweep_id and ".." not in sweep_id, \
            sweep_id
        d = os.path.join(self.root, _SWEEP_PREFIX + sweep_id)
        if create:
            os.makedirs(d, exist_ok=True)
        return d

    def sweep_dirs(self) -> list[str]:
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return []
        return [os.path.join(self.root, n) for n in names
                if n.startswith(_SWEEP_PREFIX)
                and os.path.isdir(os.path.join(self.root, n))]

    def journal(self, sweep_id: str) -> SweepJournal:
        return SweepJournal(self, sweep_id)

    def _claim_path(self, sweep_id: str, key: str) -> str:
        return os.path.join(self.sweep_dir(sweep_id), key + ".claim")

    def claim(self, sweep_id: str, key: str, owner: str,
              stale_timeout: float = 60.0) -> bool:
        """Try to claim work unit ``key`` of ``sweep_id`` for ``owner``.

        Exactly one live claimer wins (``O_CREAT|O_EXCL``).  A claim left
        behind by a crashed worker is broken once older than
        ``stale_timeout`` seconds, so its units are *reclaimed* — the
        sweep always drains."""
        path = self._claim_path(sweep_id, key)
        reclaimed = False
        while True:
            try:
                fd = os.open(path, os.O_CREAT | os.O_EXCL | os.O_WRONLY)
            except FileExistsError:
                try:
                    age = _time.time() - os.stat(path).st_mtime
                except OSError:
                    continue  # released under us: retry the O_EXCL attempt
                if age > stale_timeout:
                    # break the dead worker's claim; _break_stale's atomic
                    # rename guarantees a racing breaker can never delete
                    # a claim some third worker just re-won
                    reclaimed = _break_stale(path) or reclaimed
                    continue
                self.stats["claim_losses"] += 1
                return False
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                f.write(json.dumps({"owner": owner, "pid": os.getpid(),
                                    "time": _time.time()}))
            self.stats["claims"] += 1
            if reclaimed:
                self.stats["reclaims"] += 1
            return True

    def release_claim(self, sweep_id: str, key: str, owner: str) -> None:
        """Drop ``owner``'s claim.  A claim re-issued to someone else
        after ours went stale is left alone."""
        path = self._claim_path(sweep_id, key)
        try:
            with open(path, "r", encoding="utf-8") as f:
                if json.load(f).get("owner") != owner:
                    return
        except (OSError, ValueError):
            return
        try:
            os.remove(path)
        except OSError:
            pass

    def gc(self, max_age: float | None = None,
           max_bytes: int | None = None,
           claim_timeout: float = 3600.0) -> dict:
        """Reclaim disk: drop entries older than ``max_age`` seconds, then
        LRU-evict down to ``max_bytes`` (default: the store's own bound),
        and reap orphaned ``.tmp`` files, stale claim files and sweep
        scratch dirs older than ``max_age``.  Returns counts."""
        import shutil
        now = _time.time()
        out = {"aged": 0, "evicted": 0, "claims_reaped": 0,
               "sweeps_reaped": 0}
        if max_age is not None:
            for p in self._entries():
                try:
                    if now - os.stat(p).st_mtime > max_age:
                        os.remove(p)
                        self._own.discard(p)
                        out["aged"] += 1
                except OSError:
                    pass
        for d in self.sweep_dirs():
            try:
                if max_age is not None \
                        and now - os.stat(d).st_mtime > max_age:
                    shutil.rmtree(d, ignore_errors=True)
                    out["sweeps_reaped"] += 1
                    continue
            except OSError:
                continue
            for n in os.listdir(d):
                if not n.endswith(".claim"):
                    continue
                p = os.path.join(d, n)
                try:
                    if now - os.stat(p).st_mtime > claim_timeout:
                        os.remove(p)
                        out["claims_reaped"] += 1
                except OSError:
                    pass
        before = self.stats["evictions"]
        self._evict(max_bytes=max_bytes)
        out["evicted"] = self.stats["evictions"] - before
        self._approx_bytes = self.size_bytes()
        return out

    # -- race pins -----------------------------------------------------------
    def _pin_dir(self, create: bool = True) -> str:
        d = os.path.join(self.root, "pins")
        if create:
            os.makedirs(d, exist_ok=True)
        return d

    @staticmethod
    def pin_name(layer: str, target: str) -> str:
        raw = f"{layer}@{target}"
        return "".join(c if c.isalnum() or c in "@=-_.,x" else "_"
                       for c in raw)

    def pin(self, name: str, record: dict) -> None:
        """Atomically record a race winner (or any named best-point
        digest) under ``<root>/pins/<name>.json`` — the ``searches=``
        racing sweep pins each (layer, target)'s winning strategy/point
        here, and the warm-start index treats pins as prime seeds."""
        path = os.path.join(self._pin_dir(), name + _SUFFIX)
        fd, tmp = tempfile.mkstemp(dir=self._pin_dir(), suffix=".tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(dict(record, pin=name, time=_time.time()), f)
            os.replace(tmp, path)
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise

    def load_pin(self, name: str) -> dict | None:
        try:
            with open(os.path.join(self._pin_dir(create=False),
                                   name + _SUFFIX),
                      "r", encoding="utf-8") as f:
                return json.load(f)
        except (OSError, ValueError):
            return None

    def pins(self) -> dict[str, dict]:
        """{pin name: record} of every readable pin."""
        out = {}
        try:
            names = os.listdir(self._pin_dir(create=False))
        except FileNotFoundError:
            return out
        for n in sorted(names):
            if not n.endswith(_SUFFIX):
                continue
            rec = self.load_pin(n[:-len(_SUFFIX)])
            if rec is not None:
                out[n[:-len(_SUFFIX)]] = rec
        return out

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries())

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def keys(self) -> list[str]:
        return [os.path.basename(p)[:-len(_SUFFIX)] for p in self._entries()]

    def size_bytes(self) -> int:
        total = 0
        for p in self._entries():
            try:
                total += os.stat(p).st_size
            except OSError:
                pass
        return total

    def __repr__(self) -> str:
        return (f"ArtifactStore({self.root!r}, entries={len(self)}, "
                f"bytes={self.size_bytes()}/{self.max_bytes})")


# ---------------------------------------------------------------------------
# warm-start index — cross-layer schedule-point transfer
# ---------------------------------------------------------------------------


class WarmStartIndex:
    """Best recorded schedule points, grouped by ``ScheduleSpace``
    signature — the cross-layer warm-start substrate.

    Built from the store's sweep journals (every (layer, variant, cycles)
    point a fleet ever measured) joined with the stored entries that
    carry the actual tiling/unroll decisions, plus race pins.  Searching
    a new layer asks ``seeds(space, ...)``: points from layers whose
    schedule space has the *same shape* (equal ``space.signature()``)
    transfer verbatim; points without a recorded signature are admitted
    only if they are valid schedule points of the requesting space.
    """

    def __init__(self):
        # (cycles, tie, sig | None, tiling, unroll) — tie keeps sort total
        self._points: list[tuple] = []

    def add(self, cycles: float, sig: str | None, tiling: dict,
            unroll: int, tie: str = "") -> None:
        self._points.append((float(cycles), str(tie), sig,
                             {str(k): int(v) for k, v in tiling.items()},
                             int(unroll)))

    def __len__(self) -> int:
        return len(self._points)

    @classmethod
    def from_store(cls, store: "ArtifactStore",
                   max_entries: int = 1024) -> "WarmStartIndex":
        idx = cls()
        if store is None:
            return idx
        # journal-first: sweep journals name the keys worth reading (and
        # carry cycles for events whose entries were since evicted).
        # Candidates are fully sorted (journalled best-cycles first, then
        # key) BEFORE the max_entries cap, so the same store contents
        # always build the same index regardless of directory-listing
        # order — the reproducibility contract warm-start documents.
        journalled: dict[str, float] = {}
        for d in sorted(store.sweep_dirs()):
            sweep_id = os.path.basename(d)[len(_SWEEP_PREFIX):]
            for rec in SweepJournal(store, sweep_id).read():
                k = rec.get("key")
                if isinstance(k, str) and rec.get("cycles") is not None:
                    cyc = float(rec["cycles"])
                    journalled[k] = min(journalled.get(k, cyc), cyc)
        unjournalled = sorted(set(store.keys()) - set(journalled))
        keys = sorted(journalled, key=lambda k: (journalled[k], k)) \
            + unjournalled
        for k in keys[:max_entries]:
            entry = store.peek(k)
            if entry is None or not entry.get("tiling"):
                continue
            cycles = entry_cycles(entry)
            if cycles is None:
                continue
            s = entry.get("search") or {}
            idx.add(cycles, s.get("space_sig"), entry["tiling"],
                    entry.get("unroll_factor", 1), tie=k)
        for name, rec in store.pins().items():
            point = rec.get("point") or {}
            if point.get("tiling") and rec.get("cycles") is not None:
                idx.add(rec["cycles"], rec.get("space_sig"),
                        point["tiling"], point.get("unroll_factor", 1),
                        tie=f"pin:{name}")
        return idx

    @classmethod
    def cached_for(cls, store: "ArtifactStore") -> "WarmStartIndex":
        """``from_store`` memoised on the store instance: rebuilding scans
        every journal and peeks up to 1024 entries, far too much to repeat
        per warm-started compile of a sweep.  The cache key is a cheap
        directory census (entry/sweep/pin counts + this process's puts —
        counting, never parsing, files), so foreign writers invalidate it
        as soon as their files land."""
        try:
            n_pins = sum(n.endswith(_SUFFIX)
                         for n in os.listdir(store._pin_dir(create=False)))
        except FileNotFoundError:
            n_pins = 0
        census = (store.stats["puts"], len(store), len(store.sweep_dirs()),
                  n_pins)
        cached = getattr(store, "_warm_index", None)
        if cached is not None and cached[0] == census:
            return cached[1]
        idx = cls.from_store(store)
        store._warm_index = (census, idx)
        return idx

    def seeds(self, space, unroll_choices=(1, 2, 4, 8),
              limit: int = 4) -> list[tuple[dict, int]]:
        """Up to ``limit`` (tiling, unroll) seed points for ``space``,
        best cycles first, exact signature matches before merely
        compatible points.  Every returned tiling is re-validated against
        the requesting space (Algorithm 1), so a stale or foreign record
        can never poison a search."""
        sig = space.signature()
        vars_ = set(space.divisors)
        unrolls = tuple(unroll_choices) or (1,)
        matches, compatible = [], []
        for cycles, tie, psig, tiling, unroll in sorted(
                self._points, key=lambda p: (p[0], p[1])):
            if set(tiling) != vars_ or not space.valid(tiling):
                continue
            u = unroll if unroll in unrolls \
                else min(unrolls, key=lambda c: (abs(c - unroll), c))
            (matches if psig == sig else compatible).append((tiling, u))
        out, seen = [], set()
        for tiling, u in matches + compatible:
            key = (tuple(sorted(tiling.items())), u)
            if key in seen:
                continue
            seen.add(key)
            out.append((tiling, u))
            if len(out) >= limit:
                break
        return out


# ---------------------------------------------------------------------------
# entry (de)serialisation helpers — used by the driver
# ---------------------------------------------------------------------------


def entry_from_artifact(art) -> dict:
    """Serialise a CompiledArtifact's schedule decisions + analytics.
    Forces the default-pack cost report so a warm restore can answer
    ``cycles()`` without running a single pass."""
    art.report()  # ensure at least the default-pack report is cached
    reports = {}
    for k, val in art.ctx.state.items():
        if isinstance(k, tuple) and len(k) == 2 and k[0] == "report":
            reports[str(int(bool(k[1])))] = dataclasses.asdict(val)
    # a store-restored artifact carries its decisions in ctx.overrides
    # (state only fills on lazy rebuild); fresh compiles record them in
    # ctx.state — prefer overrides so re-persisting never loses a
    # searched/injected schedule
    tiling = art.ctx.overrides.get("tiling", art.ctx.state.get("tiling"))
    unroll = art.ctx.overrides.get("unroll_factor",
                                   art.options.unroll_factor)
    entry = {
        "codelet": art.codelet.name,
        "target": art.target,
        "options": art.options.fingerprint(),
        "pack": bool(art._default_pack()),
        "tiling": dict(tiling) if tiling is not None else None,
        "unroll_factor": int(unroll),
        "notes": list(art.schedule_notes),
        "reports": reports,
    }
    if getattr(art, "search", None) is not None:
        entry["search"] = art.search.summary()
    return entry


def reports_from_entry(entry: dict) -> dict:
    """{pack(bool): CostReport} parsed from a stored entry."""
    from .cost import CostReport
    return {bool(int(k)): CostReport(**v)
            for k, v in entry["reports"].items()}


def default_store() -> "ArtifactStore | None":
    """The process-wide store named by ``REPRO_CACHE_DIR``, if any.  An
    uncreatable directory disables the disk tier with a warning instead of
    failing every compile in the process (an *explicit*
    ``CompileOptions(store=...)`` still raises — the caller asked)."""
    path = os.environ.get(ENV_DIR)
    if not path:
        return None
    norm = os.path.abspath(os.path.expanduser(path))
    if norm in _BROKEN:
        return None
    try:
        return resolve(path)
    except OSError as e:
        import warnings
        _BROKEN.add(norm)
        warnings.warn(f"REPRO_CACHE_DIR={path!r} is unusable ({e}); "
                      f"disk artifact store disabled for this process")
        return None


def resolve(store) -> "ArtifactStore | None":
    """ArtifactStore instance | directory path | None -> store (or the
    REPRO_CACHE_DIR default, or None).  Path lookups are memoised so every
    compile against the same directory shares one stats-carrying object."""
    if store is None:
        return default_store() if os.environ.get(ENV_DIR) else None
    if isinstance(store, ArtifactStore):
        return store
    path = os.path.abspath(os.path.expanduser(os.fspath(store)))
    st = _DEFAULT.get(path)
    if st is None:
        st = _DEFAULT[path] = ArtifactStore(path)
    return st


_DEFAULT: dict[str, ArtifactStore] = {}
_BROKEN: set[str] = set()  # REPRO_CACHE_DIR paths that failed to initialise


def entry_cycles(entry: dict) -> float | None:
    """The default-pack analytic cycle count recorded in a store entry —
    what the sweep coordinator reports for deduplicated work units
    without restoring (or even LRU-bumping) the artifact."""
    try:
        rep = entry["reports"][str(int(bool(entry["pack"])))]
        return float(rep["cycles"])
    except (KeyError, TypeError, ValueError):
        return None


__all__ = ["ArtifactStore", "ENV_DIR", "FORMAT", "FRESH_GRACE", "FileLock",
           "SweepJournal", "WarmStartIndex", "compiler_signature",
           "default_store", "entry_cycles", "entry_from_artifact",
           "reports_from_entry", "resolve"]
