"""Disk-backed, size-bounded artifact store (the ISA-Mapper measurement-
database pattern, keyed like the in-process compile cache).

One JSON file per content-addressed key.  An entry does NOT pickle the
scheduled codelet — it serialises the *schedule decisions* (tiling +
unroll factor + pack), the analytic cost report(s), the pass notes and the
search digest.  A warm hit therefore restores a ``CompiledArtifact`` whose
analytics (``cycles()`` / ``report()``) work with **zero pipeline stage
executions**; the scheduled codelet and mnemonic program are rebuilt
lazily — only if ``.program`` / ``.run()`` is actually touched — by
replaying the pipeline with the stored decisions injected as pass inputs
(no tiling enumeration, no search re-run).

Robustness contract (tests/test_store.py):
* corrupt / truncated / wrong-format entries read as a miss, the bad file
  is deleted, and the caller recompiles cleanly;
* the store is size-bounded: writes evict least-recently-used entries
  (mtime order; loads bump recency) until under ``max_bytes``;
* ``clear()`` (surfaced as ``repro.clear_cache(disk=True)``) empties it.

Activate per-compile with ``CompileOptions(store=ArtifactStore(dir))`` (or
``store="dir"``), or process-wide with the ``REPRO_CACHE_DIR`` environment
variable — that is what makes multi-process sweeps replay warm.
"""
from __future__ import annotations

import dataclasses
import json
import os
import tempfile
import time as _time

FORMAT = 1
ENV_DIR = "REPRO_CACHE_DIR"
ENV_MAX_MB = "REPRO_CACHE_MAX_MB"
_SUFFIX = ".json"


def compiler_signature() -> str:
    """Digest of the stock compiler's source (pipeline stages, scheduler,
    passes, cost model, codegen).  Stamped into every store entry and
    checked on load, so a persistent REPRO_CACHE_DIR can never serve
    schedules or cycle counts produced by a *different* compiler — the
    content-addressed key only covers inputs, not the compiler itself."""
    global _SIGNATURE
    if _SIGNATURE is None:
        import hashlib
        import inspect

        from . import (codegen, cost, covenant, driver, passes, pipeline,
                       scheduler, search, spec)
        h = hashlib.sha256()
        for mod in (pipeline, scheduler, passes, cost, codegen, search,
                    driver, covenant, spec):
            try:
                h.update(inspect.getsource(mod).encode())
            except (OSError, TypeError):
                h.update(mod.__name__.encode())
        _SIGNATURE = h.hexdigest()[:16]
    return _SIGNATURE


_SIGNATURE: str | None = None


class ArtifactStore:
    """Content-addressed key -> schedule-decision entry, on disk."""

    def __init__(self, root: str, max_bytes: int | None = None):
        self.root = os.path.abspath(os.path.expanduser(os.fspath(root)))
        if max_bytes is None:
            max_bytes = int(float(os.environ.get(ENV_MAX_MB, 256)) * 2 ** 20)
        self.max_bytes = max_bytes
        os.makedirs(self.root, exist_ok=True)
        self.stats = {"hits": 0, "misses": 0, "puts": 0, "evictions": 0,
                      "corrupt": 0, "stale": 0}
        # running size estimate: puts add to it, the (O(entries)) eviction
        # scan only runs once it crosses max_bytes, then re-measures
        self._approx_bytes = self.size_bytes()

    # -- paths ---------------------------------------------------------------
    def _path(self, key: str) -> str:
        assert key and all(c in "0123456789abcdef" for c in key), key
        return os.path.join(self.root, key + _SUFFIX)

    def _entries(self) -> list[str]:
        return self._listdir(_SUFFIX)

    def _tmp_files(self) -> list[str]:
        return self._listdir(".tmp")

    def _listdir(self, suffix: str) -> list[str]:
        try:
            names = os.listdir(self.root)
        except FileNotFoundError:
            return []
        return [os.path.join(self.root, n) for n in names
                if n.endswith(suffix)]

    # -- core ops ------------------------------------------------------------
    def load(self, key: str) -> dict | None:
        """The stored entry for ``key``, or None (miss).  Anything
        unreadable — truncated JSON, foreign schema, key mismatch — is
        treated as a miss and the offending file is removed."""
        path = self._path(key)
        try:
            with open(path, "r", encoding="utf-8") as f:
                entry = json.load(f)
            if not isinstance(entry, dict) or entry.get("format") != FORMAT \
                    or entry.get("key") != key or "reports" not in entry:
                raise ValueError("foreign or incomplete entry")
        except FileNotFoundError:
            self.stats["misses"] += 1
            return None
        except Exception:
            self.stats["corrupt"] += 1
            self.stats["misses"] += 1
            try:
                os.remove(path)
            except OSError:
                pass
            return None
        if entry.get("compiler") != compiler_signature():
            # produced by a different compiler version: the schedule and
            # cycle counts may no longer be what this compiler would emit
            self.stats["stale"] += 1
            self.stats["misses"] += 1
            try:
                os.remove(path)
            except OSError:
                pass
            return None
        try:
            os.utime(path, None)  # bump LRU recency
        except OSError:
            pass
        self.stats["hits"] += 1
        return entry

    def put(self, key: str, entry: dict) -> None:
        entry = dict(entry, format=FORMAT, key=key,
                     compiler=compiler_signature())
        path = self._path(key)
        fd, tmp = tempfile.mkstemp(dir=self.root, suffix=".tmp")
        try:
            with os.fdopen(fd, "w", encoding="utf-8") as f:
                json.dump(entry, f)
            os.replace(tmp, path)  # atomic vs concurrent readers
        except BaseException:
            try:
                os.remove(tmp)
            except OSError:
                pass
            raise
        self.stats["puts"] += 1
        try:
            self._approx_bytes += os.stat(path).st_size
        except OSError:
            pass
        if self._approx_bytes > self.max_bytes:
            self._evict(keep=path)

    def invalidate(self, key: str) -> None:
        """Forget an entry that loaded but could not be restored: delete
        the file and reclassify the load as a corrupt miss."""
        try:
            os.remove(self._path(key))
        except OSError:
            pass
        self.stats["hits"] -= 1
        self.stats["misses"] += 1
        self.stats["corrupt"] += 1

    def _evict(self, keep: str | None = None) -> None:
        """Drop least-recently-used entries until under ``max_bytes``;
        ``keep`` (the just-written path) is never a victim, even under
        mtime ties on coarse-timestamp filesystems, so a put always
        sticks.  Also reaps stale ``.tmp`` leftovers of interrupted puts —
        they are invisible to loads, so without this they would
        accumulate unbounded."""
        now = _time.time()
        for p in self._tmp_files():
            try:
                if now - os.stat(p).st_mtime > 600:
                    os.remove(p)
            except OSError:
                pass
        files = []
        for p in self._entries():
            try:
                st = os.stat(p)
            except OSError:
                continue
            files.append((st.st_mtime, st.st_size, p))
        files.sort()
        total = sum(sz for _, sz, _ in files)
        if keep is None and files:
            keep = files[-1][2]  # protect the most recent entry
        victims = [f for f in files if f[2] != keep]
        while victims and total > self.max_bytes:
            _, sz, victim = victims.pop(0)
            try:
                os.remove(victim)
            except OSError:
                continue
            total -= sz
            self.stats["evictions"] += 1
        self._approx_bytes = total

    def clear(self) -> None:
        for p in self._entries() + self._tmp_files():
            try:
                os.remove(p)
            except OSError:
                pass
        self._approx_bytes = 0

    # -- introspection -------------------------------------------------------
    def __len__(self) -> int:
        return len(self._entries())

    def __contains__(self, key: str) -> bool:
        return os.path.exists(self._path(key))

    def keys(self) -> list[str]:
        return [os.path.basename(p)[:-len(_SUFFIX)] for p in self._entries()]

    def size_bytes(self) -> int:
        total = 0
        for p in self._entries():
            try:
                total += os.stat(p).st_size
            except OSError:
                pass
        return total

    def __repr__(self) -> str:
        return (f"ArtifactStore({self.root!r}, entries={len(self)}, "
                f"bytes={self.size_bytes()}/{self.max_bytes})")


# ---------------------------------------------------------------------------
# entry (de)serialisation helpers — used by the driver
# ---------------------------------------------------------------------------


def entry_from_artifact(art) -> dict:
    """Serialise a CompiledArtifact's schedule decisions + analytics.
    Forces the default-pack cost report so a warm restore can answer
    ``cycles()`` without running a single pass."""
    art.report()  # ensure at least the default-pack report is cached
    reports = {}
    for k, val in art.ctx.state.items():
        if isinstance(k, tuple) and len(k) == 2 and k[0] == "report":
            reports[str(int(bool(k[1])))] = dataclasses.asdict(val)
    # a store-restored artifact carries its decisions in ctx.overrides
    # (state only fills on lazy rebuild); fresh compiles record them in
    # ctx.state — prefer overrides so re-persisting never loses a
    # searched/injected schedule
    tiling = art.ctx.overrides.get("tiling", art.ctx.state.get("tiling"))
    unroll = art.ctx.overrides.get("unroll_factor",
                                   art.options.unroll_factor)
    entry = {
        "codelet": art.codelet.name,
        "target": art.target,
        "options": art.options.fingerprint(),
        "pack": bool(art._default_pack()),
        "tiling": dict(tiling) if tiling is not None else None,
        "unroll_factor": int(unroll),
        "notes": list(art.schedule_notes),
        "reports": reports,
    }
    if getattr(art, "search", None) is not None:
        entry["search"] = art.search.summary()
    return entry


def reports_from_entry(entry: dict) -> dict:
    """{pack(bool): CostReport} parsed from a stored entry."""
    from .cost import CostReport
    return {bool(int(k)): CostReport(**v)
            for k, v in entry["reports"].items()}


def default_store() -> "ArtifactStore | None":
    """The process-wide store named by ``REPRO_CACHE_DIR``, if any.  An
    uncreatable directory disables the disk tier with a warning instead of
    failing every compile in the process (an *explicit*
    ``CompileOptions(store=...)`` still raises — the caller asked)."""
    path = os.environ.get(ENV_DIR)
    if not path:
        return None
    norm = os.path.abspath(os.path.expanduser(path))
    if norm in _BROKEN:
        return None
    try:
        return resolve(path)
    except OSError as e:
        import warnings
        _BROKEN.add(norm)
        warnings.warn(f"REPRO_CACHE_DIR={path!r} is unusable ({e}); "
                      f"disk artifact store disabled for this process")
        return None


def resolve(store) -> "ArtifactStore | None":
    """ArtifactStore instance | directory path | None -> store (or the
    REPRO_CACHE_DIR default, or None).  Path lookups are memoised so every
    compile against the same directory shares one stats-carrying object."""
    if store is None:
        return default_store() if os.environ.get(ENV_DIR) else None
    if isinstance(store, ArtifactStore):
        return store
    path = os.path.abspath(os.path.expanduser(os.fspath(store)))
    st = _DEFAULT.get(path)
    if st is None:
        st = _DEFAULT[path] = ArtifactStore(path)
    return st


_DEFAULT: dict[str, ArtifactStore] = {}
_BROKEN: set[str] = set()  # REPRO_CACHE_DIR paths that failed to initialise


__all__ = ["ArtifactStore", "ENV_DIR", "FORMAT", "compiler_signature",
           "default_store", "entry_from_artifact", "reports_from_entry",
           "resolve"]
