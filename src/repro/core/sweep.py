"""Multi-process sweep coordinator over the shared artifact store.

The paper's compiler wins by evaluating *many* execution mappings per
layer per architecture; this module is that loop at fleet scale.  A
**sweep plan** is the cross product

    layers x target variants x (optional) search configs

expanded into **work units** whose identity is the driver's
content-addressed compile key — ``(codelet fingerprint, covenant-spec
fingerprint, options fingerprint, pipeline fingerprint)`` — exactly the
key the in-process cache and the disk ``ArtifactStore`` use.  That shared
identity is what makes the coordinator correct by construction:

* **dedup** — units whose key already sits in the store are reported
  straight from the stored entry (``store.peek``), never dispatched;
* **partition** — remaining units are sharded across N worker processes
  deterministically (key-sorted round robin: a function of the unit-key
  set and N only, independent of plan order);
* **merge** — every worker compiles *through the driver* with the store
  configured, so results land in the shared measurement database and the
  coordinator's ``SweepReport`` is just the union of unit records.

Three backends:

* ``serial`` — in-process, the reference semantics (``SweepReport`` merge
  identity vs a plain ``compile_many`` is a test invariant);
* ``process`` — the coordinator forks/spawns N workers
  (``multiprocessing``) over a static partition;
* ``external`` — *this* process is one of N independently launched
  workers (``python -m repro.sweep ... --external``) that claim units
  through store-side claim files (``ArtifactStore.claim``) with a
  stale-claim timeout, so a crashed worker's units are reclaimed by the
  survivors and the sweep always drains.

Every unit outcome is appended to the store's monotonic ``SweepJournal``;
CI asserts "each work unit compiled exactly once, warm re-runs recompile
nothing" as pure journal queries (``python -m repro.sweep
--assert-unique-compiles --expect-store-hits``).
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
from typing import Iterable, Sequence

from . import library as library_mod
from . import store as store_mod
from .codelet import Codelet
from .pipeline import CompileOptions
from .search import SearchOptions

# ---------------------------------------------------------------------------
# workload descriptors — the serialisable half of a work unit
# ---------------------------------------------------------------------------

# A workload is ("kind", payload) where payload is JSON-able for every
# kind except "local" (an in-memory Codelet/builder: serial backend only).
_BUILDERS = {
    "gemm": library_mod.gemm,
    "fc": library_mod.fc,
    "conv2d": library_mod.conv2d,
    "elementwise": library_mod.elementwise,
}


def workload_of(layer) -> tuple:
    """Normalise a sweep ``layers`` item into a workload descriptor.

    Accepts paper-layer keys, ``library.LayerSpec``, launch-layer GEMM
    records (anything with ``tokens``/``n``/``k``/``name``), explicit
    ``("gemm"|"fc"|"conv2d"|"elementwise", {kwargs})`` descriptors, and —
    for the serial backend only — raw Codelets or builder thunks."""
    if isinstance(layer, str):
        return ("paper", layer)
    if isinstance(layer, library_mod.LayerSpec):
        if any(s.key == layer.key for s in library_mod.PAPER_LAYERS):
            return ("paper", layer.key)
        return ("local", layer.build)
    if all(hasattr(layer, a) for a in ("tokens", "n", "k", "name")):
        # launch.layers.LayerGemm (duck-typed: launch depends on jax,
        # the sweep core must not)
        return ("gemm", {"m": int(layer.tokens), "n": int(layer.n),
                         "k": int(layer.k), "name": str(layer.name)})
    if isinstance(layer, tuple) and len(layer) == 2 \
            and layer[0] in _BUILDERS and isinstance(layer[1], dict):
        return (layer[0], dict(layer[1]))
    if isinstance(layer, Codelet) or callable(layer):
        return ("local", layer)
    raise TypeError(f"cannot express {layer!r} as a sweep workload")


def build_workload(workload: tuple) -> Codelet:
    kind, payload = workload
    if kind == "paper":
        return library_mod.paper_layer(payload)
    if kind == "local":
        return payload() if callable(payload) else payload
    builder = _BUILDERS.get(kind)
    if builder is None:
        raise ValueError(f"unknown workload kind {kind!r}")
    return builder(**payload)


def _workload_serialisable(workload: tuple) -> bool:
    return workload[0] != "local"


def _workload_label(workload: tuple) -> str:
    kind, payload = workload
    if kind == "paper":
        return payload
    if kind == "local":
        obj = payload
        name = getattr(obj, "name", None) or getattr(obj, "__name__", None)
        return str(name or "local")
    if kind == "gemm" and "name" in payload:
        return str(payload["name"])
    return f"{kind}:" + ",".join(f"{k}={v}"
                                 for k, v in sorted(payload.items()))


# ---------------------------------------------------------------------------
# options (de)serialisation — JSON plans for external/spawned workers
# ---------------------------------------------------------------------------

_OPTION_FIELDS = ("vectorize", "unroll", "pack", "unroll_factor",
                  "max_mnemonics", "check_covenant")


def options_to_json(opts: CompileOptions) -> dict:
    d = {f: getattr(opts, f) for f in _OPTION_FIELDS}
    if opts.search is not None:
        d["search"] = dataclasses.asdict(opts.search)
    return d


def options_from_json(d: dict) -> CompileOptions:
    search = None
    if d.get("search") is not None:
        s = dict(d["search"])
        s["unroll_choices"] = tuple(s.get("unroll_choices", (1, 2, 4, 8)))
        search = SearchOptions(**s)
    return CompileOptions(**{f: d[f] for f in _OPTION_FIELDS if f in d},
                          search=search)


def _options_label(opts: CompileOptions) -> str:
    if opts.search is not None:
        return (f"search:{opts.search.strategy}"
                f"@g{opts.search.generations}p{opts.search.population}"
                f"s{opts.search.seed}")
    return "heuristic"


# ---------------------------------------------------------------------------
# work units + results
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class WorkUnit:
    """One ``(codelet, target-variant, options)`` point of a sweep plan,
    identified by the driver's content-addressed compile ``key``."""

    layer: str            # display label (paper key / codelet name)
    target: str           # registry name, incl. derived variants
    workload: tuple       # serialisable descriptor (see workload_of)
    options: CompileOptions
    key: str              # = repro.core.driver.compile_key(...)

    @property
    def opt(self) -> str:
        return _options_label(self.options)

    def to_json(self) -> dict:
        assert _workload_serialisable(self.workload), \
            f"local workload {self.layer!r} cannot cross a process boundary"
        return {"layer": self.layer, "target": self.target,
                "workload": list(self.workload),
                "options": options_to_json(self.options), "key": self.key}

    @classmethod
    def from_json(cls, d: dict) -> "WorkUnit":
        return cls(layer=d["layer"], target=d["target"],
                   workload=tuple(d["workload"]),
                   options=options_from_json(d["options"]), key=d["key"])


@dataclasses.dataclass
class UnitResult:
    """Outcome of one work unit.

    ``source``: ``compiled`` (ran the pipeline/search), ``store`` (warm
    artifact-store restore — zero pipeline stages), ``cache`` (in-process
    cache hit), ``dedup`` (coordinator skipped dispatch: the key was
    already in the store), ``none`` (failed/skipped before compiling)."""

    key: str
    layer: str
    target: str
    opt: str = "heuristic"
    status: str = "ok"          # ok | failed | skipped
    source: str = "none"
    cycles: float | None = None
    stages_run: int = 0
    worker: str = "coordinator"
    error: str | None = None

    def to_json(self) -> dict:
        return dataclasses.asdict(self)

    @classmethod
    def from_json(cls, d: dict) -> "UnitResult":
        return cls(**d)


_STATUS_RANK = {"ok": 0, "failed": 1, "skipped": 2}


@dataclasses.dataclass
class SweepReport:
    """Merged outcome of a sweep: per-unit records + roll-ups.

    ``merge`` is associative and idempotent over unit keys (the best
    record per key wins: ok > failed > skipped), so partial reports from
    any number of workers — or from a re-run — combine into the same
    final report.  ``pins`` records the winners a ``race=True`` sweep
    pinned in the store (coordinator-side, attached after the merge)."""

    sweep_id: str
    results: list[UnitResult] = dataclasses.field(default_factory=list)
    backend: str = "serial"
    workers: int = 1
    pins: list[dict] = dataclasses.field(default_factory=list)

    # -- roll-ups ------------------------------------------------------------
    def counts(self) -> dict:
        c = {"units": len(self.results), "ok": 0, "failed": 0, "skipped": 0,
             "compiled": 0, "store": 0, "cache": 0, "dedup": 0}
        for r in self.results:
            c[r.status] = c.get(r.status, 0) + 1
            if r.source in c:
                c[r.source] += 1
        return c

    @property
    def ok(self) -> list[UnitResult]:
        return [r for r in self.results if r.status == "ok"]

    def stages_run(self) -> int:
        return sum(r.stages_run for r in self.results)

    def cycles_by_key(self) -> dict:
        return {r.key: r.cycles for r in self.ok}

    def best_by_layer(self) -> dict:
        """{layer: winning UnitResult} — lowest analytic cycles across the
        target-variant x options axes (the fig14 table)."""
        best: dict[str, UnitResult] = {}
        for r in self.ok:
            if r.cycles is None:
                continue
            cur = best.get(r.layer)
            if cur is None or r.cycles < cur.cycles:
                best[r.layer] = r
        return best

    def best_table(self) -> str:
        best = self.best_by_layer()
        if not best:
            return "(no successful units)"
        width = max(len(k) for k in best)
        lines = [f"{'layer':{width}s} {'best variant':>28s} "
                 f"{'options':>24s} {'cycles':>14s}"]
        for layer in sorted(best):
            r = best[layer]
            lines.append(f"{layer:{width}s} {r.target:>28s} "
                         f"{r.opt:>24s} {r.cycles:14.0f}")
        return "\n".join(lines)

    def race_table(self) -> str:
        """Human-readable table of the strategy race winners (``pins``)."""
        if not self.pins:
            return "(no race winners pinned)"
        width = max(len(p["layer"]) for p in self.pins)
        lines = [f"{'layer':{width}s} {'target':>24s} {'winner':>14s} "
                 f"{'cycles':>14s}"]
        for p in sorted(self.pins, key=lambda p: (p["layer"], p["target"])):
            lines.append(f"{p['layer']:{width}s} {p['target']:>24s} "
                         f"{p['strategy']:>14s} {p['cycles']:14.0f}")
        return "\n".join(lines)

    def summary(self) -> str:
        c = self.counts()
        pinned = f", {len(self.pins)} winners pinned" if self.pins else ""
        return (f"sweep {self.sweep_id}: {c['units']} units via "
                f"{self.backend}x{self.workers} — {c['ok']} ok "
                f"({c['compiled']} compiled, {c['store']} store, "
                f"{c['cache']} cache, {c['dedup']} dedup), "
                f"{c['failed']} failed, {c['skipped']} skipped, "
                f"{self.stages_run()} pipeline stages run{pinned}")

    # -- merge ---------------------------------------------------------------
    @classmethod
    def merge(cls, reports: "Iterable[SweepReport]",
              sweep_id: str | None = None) -> "SweepReport":
        by_key: dict[str, UnitResult] = {}
        sid, backend, workers = sweep_id, "serial", 0
        for rep in reports:
            sid = sid or rep.sweep_id
            backend = rep.backend
            workers = max(workers, rep.workers)
            for r in rep.results:
                cur = by_key.get(r.key)
                if cur is None or _STATUS_RANK.get(r.status, 3) \
                        < _STATUS_RANK.get(cur.status, 3):
                    by_key[r.key] = r
        out = cls(sweep_id=sid or "?", backend=backend,
                  workers=max(workers, 1))
        out.results = sorted(by_key.values(), key=lambda r: r.key)
        return out

    # -- (de)serialisation ---------------------------------------------------
    def to_json(self) -> dict:
        return {"sweep_id": self.sweep_id, "backend": self.backend,
                "workers": self.workers, "pins": list(self.pins),
                "results": [r.to_json() for r in self.results]}

    @classmethod
    def from_json(cls, d: dict) -> "SweepReport":
        return cls(sweep_id=d["sweep_id"], backend=d.get("backend", "?"),
                   workers=d.get("workers", 1), pins=d.get("pins", []),
                   results=[UnitResult.from_json(r) for r in d["results"]])

    def save(self, path: str) -> None:
        with open(path, "w", encoding="utf-8") as f:
            json.dump(self.to_json(), f, indent=1)

    @classmethod
    def load(cls, path: str) -> "SweepReport":
        with open(path, "r", encoding="utf-8") as f:
            return cls.from_json(json.load(f))


# ---------------------------------------------------------------------------
# plan expansion + deterministic partition
# ---------------------------------------------------------------------------


def expand_plan(layers: Iterable, targets: Sequence[str] = ("hvx",),
                options: CompileOptions | None = None,
                searches: Sequence[SearchOptions | None] | None = None,
                ) -> list[WorkUnit]:
    """layers x targets x search configs -> key-sorted, key-deduped work
    units.  ``searches`` adds an options axis: each entry replaces
    ``options.search`` (``None`` = the one-shot heuristic)."""
    from . import driver as driver_mod  # local: driver imports sweep lazily

    base = options if options is not None else CompileOptions()
    if getattr(base, "store", None) is not None:
        base = dataclasses.replace(base, store=None)  # location, not input
    axis = [base] if not searches else \
        [dataclasses.replace(base, search=s) for s in searches]
    units: dict[str, WorkUnit] = {}
    for layer in layers:
        workload = workload_of(layer)
        cdlt = build_workload(workload)
        label = _workload_label(workload)
        for target in targets:
            if not isinstance(target, str):
                raise TypeError(
                    f"sweep targets must be registry names (got "
                    f"{type(target)!r}); register the spec first")
            for opts in axis:
                key = driver_mod.compile_key(cdlt, target, opts)
                units.setdefault(key, WorkUnit(
                    layer=label, target=target, workload=workload,
                    options=opts, key=key))
    return sorted(units.values(), key=lambda u: u.key)


def partition(units: Sequence[WorkUnit],
              workers: int) -> list[list[WorkUnit]]:
    """Shard units across ``workers`` deterministically: key-sorted round
    robin.  A pure function of the unit-key set and ``workers`` — plan
    order, duplicates and process identity do not change the shards."""
    assert workers >= 1
    shards: list[list[WorkUnit]] = [[] for _ in range(workers)]
    for i, u in enumerate(sorted(units, key=lambda u: u.key)):
        shards[i % workers].append(u)
    return shards


def plan_id(units: Sequence[WorkUnit]) -> str:
    """Stable sweep id: digest of the sorted unit-key set.  Cold and warm
    runs of the same plan share a journal — "compiled exactly once" holds
    *across* runs, which is the CI invariant."""
    h = hashlib.sha256()
    for u in sorted(units, key=lambda u: u.key):
        h.update(u.key.encode())
        h.update(b"\x00")
    return h.hexdigest()[:12]


# ---------------------------------------------------------------------------
# unit execution (shared by every backend)
# ---------------------------------------------------------------------------


def _journal_safe(journal, record: dict) -> None:
    """Journaling is telemetry: a wedged/raced journal lock must never
    fail a unit whose compile already landed in the store.  A dropped
    'compiled' event is still surfaced — the CLI's
    ``--assert-unique-compiles`` reports units that compiled without a
    journal entry."""
    if journal is None:
        return
    try:
        journal.append(record)
    except Exception:
        pass


def _compile_unit(unit: WorkUnit, store, journal, worker: str) -> UnitResult:
    """Compile one unit through the driver, classify the source from the
    driver's stats delta, and journal the outcome."""
    from . import driver as driver_mod

    opts = unit.options if store is None \
        else dataclasses.replace(unit.options, store=store)
    before = driver_mod.cache_stats()
    try:
        art = driver_mod.compile(build_workload(unit.workload), unit.target,
                                 opts)
        cycles = art.cycles()
    except Exception as e:  # a broken covenant/unit must not sink the sweep
        res = UnitResult(key=unit.key, layer=unit.layer, target=unit.target,
                         opt=unit.opt, status="failed", error=str(e),
                         worker=worker)
        _journal_safe(journal, {"event": "failed", "key": unit.key,
                                "layer": unit.layer, "target": unit.target,
                                "worker": worker, "error": str(e)[:500]})
        return res
    after = driver_mod.cache_stats()
    if after["store_hits"] > before["store_hits"]:
        source, event = "store", "store_hit"
    elif after["hits"] > before["hits"]:
        source, event = "cache", "cache_hit"
    else:
        source, event = "compiled", "compiled"
    res = UnitResult(key=unit.key, layer=unit.layer, target=unit.target,
                     opt=unit.opt, status="ok", source=source, cycles=cycles,
                     stages_run=len(art.ctx.executed), worker=worker)
    _journal_safe(journal, {"event": event, "key": unit.key,
                            "layer": unit.layer, "target": unit.target,
                            "worker": worker, "cycles": cycles})
    return res


def _dedup_result(unit: WorkUnit, entry: dict, worker: str) -> UnitResult:
    return UnitResult(key=unit.key, layer=unit.layer, target=unit.target,
                      opt=unit.opt, status="ok", source="dedup",
                      cycles=store_mod.entry_cycles(entry), stages_run=0,
                      worker=worker)


# ---------------------------------------------------------------------------
# process backend
# ---------------------------------------------------------------------------


def _run_worker_shard(payload: str) -> str:
    """Top-level worker entry (spawn-importable).  JSON in, JSON out —
    no pickled live objects cross the process boundary."""
    import repro

    args = json.loads(payload)
    repro.clear_cache()  # forked workers must not inherit warm in-process
    #                      state: unit sources stay store/compiled only
    store = store_mod.resolve(args["store"]) if args["store"] else None
    journal = store.journal(args["sweep_id"]) if store is not None else None
    worker = args["worker"]
    results = []
    for d in args["units"]:
        unit = WorkUnit.from_json(d)
        results.append(_compile_unit(unit, store, journal, worker).to_json())
    return json.dumps(results)


def _process_backend(shards: list[list[WorkUnit]], store, sweep_id: str,
                     mp_start: str | None = None) -> list[UnitResult]:
    import multiprocessing as mp

    if mp_start is None:
        mp_start = "fork" if "fork" in mp.get_all_start_methods() \
            else "spawn"
    ctx = mp.get_context(mp_start)
    payloads, labels = [], []
    for i, shard in enumerate(shards):
        if not shard:
            continue
        worker = f"w{i}"
        labels.append((worker, shard))
        payloads.append(json.dumps({
            "units": [u.to_json() for u in shard],
            "store": store.root if store is not None else None,
            "sweep_id": sweep_id, "worker": worker}))
    if not payloads:
        return []
    results: list[UnitResult] = []
    # one future per shard on a ProcessPoolExecutor: a worker dying hard
    # (segfault/OOM) raises BrokenProcessPool instead of wedging the
    # coordinator (the mp.Pool failure mode), and it fails only the
    # shards that had not finished — completed shards keep their results,
    # and every finished unit is in the store either way
    from concurrent.futures import ProcessPoolExecutor

    with ProcessPoolExecutor(max_workers=len(payloads),
                             mp_context=ctx) as pool:
        futures = [pool.submit(_run_worker_shard, p) for p in payloads]
        for (worker, shard), fut in zip(labels, futures):
            try:
                out = fut.result()
            except Exception as e:
                results.extend(
                    UnitResult(key=u.key, layer=u.layer, target=u.target,
                               opt=u.opt, status="failed",
                               error=f"worker {worker} died: {e}",
                               worker=worker)
                    for u in shard)
                continue
            results.extend(UnitResult.from_json(d) for d in json.loads(out))
    return results


# ---------------------------------------------------------------------------
# external (claim-based) backend
# ---------------------------------------------------------------------------


class _ClaimHeartbeat:
    """Touch a held claim file on a background timer while its unit
    compiles, so a unit that legitimately takes longer than the
    stale-claim timeout (search-enabled compiles, huge layers) is never
    mistaken for a crashed worker's and double-compiled.  A worker that
    really dies stops beating, its claim ages out, and the unit is
    reclaimed — exactly the intended split."""

    def __init__(self, path: str, interval: float):
        import threading
        self.path = path
        self.interval = max(interval, 0.05)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._beat, daemon=True)

    def _beat(self) -> None:
        while not self._stop.wait(self.interval):
            try:
                os.utime(self.path, None)
            except OSError:
                return  # claim gone (released/broken): nothing to keep warm

    def __enter__(self) -> "_ClaimHeartbeat":
        self._thread.start()
        return self

    def __exit__(self, *exc) -> None:
        self._stop.set()
        self._thread.join(timeout=5)


def run_external_worker(units: Sequence[WorkUnit], store, worker: str,
                        sweep_id: str | None = None,
                        stale_claim_timeout: float = 60.0,
                        drain_timeout: float | None = None) -> SweepReport:
    """Act as one independently launched worker of a fleet: walk the plan
    in key order, skip units already stored, claim the rest through
    store-side claim files, compile, journal, release.  Claims older than
    ``stale_claim_timeout`` (a crashed worker) are broken and reclaimed;
    held claims are heartbeat-refreshed while their unit compiles.

    Units another live worker holds are re-visited until they appear in
    the store (that worker finished) or their claim goes stale and is
    reclaimed (that worker died) — so the *last surviving* worker still
    drains the whole plan.  ``drain_timeout`` (default: 10x the stale
    timeout) bounds that wait; units still held by a live-and-beating
    claim when it expires are reported ``skipped``."""
    import time as time_mod

    if store is None:
        raise ValueError("external workers need a shared ArtifactStore")
    sweep_id = sweep_id or plan_id(units)
    if drain_timeout is None:
        drain_timeout = 10 * stale_claim_timeout
    journal = store.journal(sweep_id)
    done: dict[str, UnitResult] = {}
    pending = sorted(units, key=lambda u: u.key)
    deadline = time_mod.monotonic() + drain_timeout
    while pending:
        waiting = []
        for unit in pending:
            entry = store.peek(unit.key)
            if entry is not None:
                done[unit.key] = _dedup_result(unit, entry, worker)
                continue
            if not store.claim(sweep_id, unit.key, worker,
                               stale_timeout=stale_claim_timeout):
                done[unit.key] = UnitResult(
                    key=unit.key, layer=unit.layer, target=unit.target,
                    opt=unit.opt, status="skipped", source="none",
                    worker=worker, error="claimed by another worker")
                waiting.append(unit)
                continue
            try:
                with _ClaimHeartbeat(store._claim_path(sweep_id, unit.key),
                                     stale_claim_timeout / 3):
                    done[unit.key] = _compile_unit(unit, store, journal,
                                                   worker)
            finally:
                store.release_claim(sweep_id, unit.key, worker)
        pending = waiting
        if pending and time_mod.monotonic() >= deadline:
            break
        if pending:
            time_mod.sleep(min(1.0, stale_claim_timeout / 4))
    results = [done[k] for k in sorted(done)]
    return SweepReport(sweep_id=sweep_id, results=results,
                       backend="external", workers=1)


# ---------------------------------------------------------------------------
# strategy racing — pin the per-(layer, target) winner in the store
# ---------------------------------------------------------------------------


def _pin_race_winners(units: Sequence[WorkUnit], report: SweepReport,
                      store, journal) -> list[dict]:
    """Race the ``searches=`` axis: among each (layer, target)'s search
    units pick the lowest-cycles winner, write it as a store pin
    (``ArtifactStore.pin``) and journal a ``pinned`` event.  Returns the
    pin records (also attached to the report).  Winners feed the
    warm-start index, so a race permanently upgrades later searches of
    same-shaped layers."""
    reported = {r.key: r for r in report.ok if r.cycles is not None}
    groups: dict[tuple[str, str], list[tuple[float, WorkUnit]]] = {}
    for u in units:
        if u.options.search is None:
            continue
        # trust the store over this worker's partial view: a unit another
        # fleet member compiled (this report says skipped/failed) must
        # still race, or a drain-timeout could pin the losing strategy
        r = reported.get(u.key)
        cycles = r.cycles if r is not None else \
            store_mod.entry_cycles(store.peek(u.key) or {})
        if cycles is None:
            continue
        groups.setdefault((u.layer, u.target), []).append((cycles, u))
    pins: list[dict] = []
    for (layer, target), cs in sorted(groups.items()):
        # a rival strategy failing must not cost the group its pin: the
        # surviving strategies still raced (the plan guaranteed >= 2),
        # and the best of them is strictly better than no record at all
        cycles, unit = min(cs, key=lambda cu: (cu[0], cu[1].key))
        entry = store.peek(unit.key) or {}
        search = entry.get("search") or {}
        rec = {"layer": layer, "target": target, "key": unit.key,
               "strategy": unit.options.search.strategy,
               "opt": unit.opt, "cycles": cycles,
               "point": {"tiling": entry.get("tiling"),
                         "unroll_factor": entry.get("unroll_factor", 1)},
               "space_sig": search.get("space_sig"),
               "raced": sorted(u.opt for _, u in cs)}
        store.pin(store.pin_name(layer, target), rec)
        pins.append(rec)
        _journal_safe(journal, {"event": "pinned", "key": unit.key,
                                "layer": layer, "target": target,
                                "worker": "coordinator",
                                "cycles": cycles,
                                "strategy": rec["strategy"]})
    return pins


# ---------------------------------------------------------------------------
# the coordinator
# ---------------------------------------------------------------------------


def sweep(layers: Iterable, targets: Sequence[str] = ("hvx",), *,
          options: CompileOptions | None = None,
          searches: Sequence[SearchOptions | None] | None = None,
          workers: int = 1, store=None, backend: str | None = None,
          sweep_id: str | None = None, dedup: bool = True,
          race: bool = False,
          stale_claim_timeout: float = 60.0,
          mp_start: str | None = None) -> SweepReport:
    """Run a sweep plan and merge the outcome into a ``SweepReport``.

    ``layers`` — paper-layer keys / ``LayerSpec`` / launch GEMM records /
    ``("gemm", {...})`` descriptors (serial backend also takes raw
    Codelets); ``targets`` — registry names incl. derived variants
    (``"dnnweaver@pe=32x32"``); ``searches`` — optional third axis of
    ``SearchOptions`` (``None`` entry = heuristic).

    ``store`` (or ``REPRO_CACHE_DIR``) names the shared measurement
    database; with one configured, already-stored units are *deduplicated*
    (reported, not dispatched) and every worker compile lands in the store
    and the sweep journal.  ``backend`` defaults to ``process`` when
    ``workers > 1`` else ``serial``; ``external`` turns this process into
    one claim-based worker of an independently launched fleet.

    ``race=True`` treats the ``searches=`` axis as a per-layer strategy
    race: every strategy runs under its own (equal) budget, and each
    (layer, target)'s lowest-cycles winner is *pinned* in the store
    (``report.pins`` / ``report.race_table()``) for later compiles and
    warm-started searches to reuse."""
    if store is None and options is not None \
            and getattr(options, "store", None) is not None:
        store = options.store  # honour the compile()/compile_many() idiom
    st = store_mod.resolve(store)
    if race:
        if st is None:
            raise ValueError("race=True needs a shared ArtifactStore to "
                             "pin winners in")
        if not searches or sum(s is not None for s in searches) < 2:
            raise ValueError("race=True needs a searches= axis of at "
                             "least two strategies to race")
    units = expand_plan(layers, targets, options=options, searches=searches)
    sweep_id = sweep_id or plan_id(units)
    if backend is None:
        backend = "process" if workers > 1 else "serial"
    if backend == "external":
        report = run_external_worker(units, st, worker=f"pid{os.getpid()}",
                                     sweep_id=sweep_id,
                                     stale_claim_timeout=stale_claim_timeout)
        if race:
            report.pins = _pin_race_winners(units, report, st,
                                            st.journal(sweep_id))
        return report

    results: list[UnitResult] = []
    todo: list[WorkUnit] = []
    journal = st.journal(sweep_id) if st is not None else None
    for unit in units:
        entry = st.peek(unit.key) if (dedup and st is not None) else None
        if entry is not None:
            res = _dedup_result(unit, entry, "coordinator")
            if res.cycles is None:
                # entry present but unreadable analytics: recompile
                todo.append(unit)
                continue
            _journal_safe(journal, {"event": "dedup", "key": unit.key,
                                    "layer": unit.layer,
                                    "target": unit.target,
                                    "worker": "coordinator",
                                    "cycles": res.cycles})
            results.append(res)
        else:
            todo.append(unit)

    if backend == "process" and workers > 1 and todo:
        serialisable = [u for u in todo
                        if _workload_serialisable(u.workload)]
        local = [u for u in todo if not _workload_serialisable(u.workload)]
        shards = partition(serialisable, workers)
        results.extend(_process_backend(shards, st, sweep_id,
                                        mp_start=mp_start))
        for unit in local:  # raw codelets cannot cross processes
            results.append(_compile_unit(unit, st, journal, "coordinator"))
    elif backend in ("serial", "process"):
        for unit in todo:
            results.append(_compile_unit(unit, st, journal, "coordinator"))
    else:
        raise ValueError(f"unknown sweep backend {backend!r}")

    report = SweepReport.merge(
        [SweepReport(sweep_id=sweep_id, results=results)],
        sweep_id=sweep_id)
    report.backend = backend
    report.workers = workers
    if race:
        report.pins = _pin_race_winners(units, report, st, journal)
    return report


__all__ = ["SweepReport", "UnitResult", "WorkUnit", "build_workload",
           "expand_plan", "options_from_json", "options_to_json",
           "partition", "plan_id", "run_external_worker", "sweep",
           "workload_of"]
