"""Codelet library: the paper's DNN-layer set.

Each builder returns a *layer-mapped* Codelet (Fig 7b): shapes/dtypes bound,
locations still ``null`` — exactly the state the Covenant pipeline starts
from.  ``PAPER_LAYERS`` instantiates Table 2 verbatim (BERT-Large GEMM +
attention GEMMs, DLRM FCs, InceptionV3 / MobileNetV3 / ResNet-50 convs+FCs);
N is sequence length for language models and batch size otherwise; INT8
inputs/weights, INT32 outputs (§5.1).
"""
from __future__ import annotations

import dataclasses

import numpy as np

from .codelet import Codelet, Compute, Loop, Transfer, ref, v
from .dtypes import dt

# ---------------------------------------------------------------------------
# generic builders
# ---------------------------------------------------------------------------


def elementwise(op: str, n: int, dtype: str = "i32", arity: int = 2) -> Codelet:
    """``add``/``mul``/``relu``/... over flat length-n tensors (Fig 7)."""
    c = Codelet(f"{op.lower()}{n}")
    c.param("N", n)
    a = c.inp("a", [n], dtype)
    srcs = [a]
    if arity == 2:
        srcs.append(c.inp("b", [n], dtype))
    o = c.out("c", [n], dtype)
    body = Compute(op.upper(), ref(o, v("n")),
                   tuple(ref(s, v("n")) for s in srcs),
                   roles={"n": ["n"]}, dtype=dt(dtype))
    c.body.append(Loop("n", 0, n, 1, [body]))

    def oracle(inputs, _op=op.upper(), _dt=dt(dtype)):
        from .semantics import apply_elementwise
        ins = [inputs["a"]] + ([inputs["b"]] if arity == 2 else [])
        return {"c": apply_elementwise(_op, _dt.np, [np.asarray(x) for x in ins])}

    c.oracle = oracle
    return c


def gemm(m: int, n: int, k: int, *, heads: int = 1, name: str | None = None,
         in_dtype: str = "i8", acc_dtype: str = "i32") -> Codelet:
    """C[h,m,n] += A[h,m,k] * B[h,k,n] — the FC/GEMM/attention-GEMM workhorse.

    The single compute op is a scalar-granularity MAC; vectorization re-maps
    it onto whatever GEMM-family capability the target exposes (§3.2's
    capability decomposition in reverse).
    """
    c = Codelet(name or f"gemm_{m}x{n}x{k}" + (f"_h{heads}" if heads > 1 else ""))
    for pname, val in (("M", m), ("N", n), ("K", k), ("H", heads)):
        c.param(pname, val)
    hdims = [heads] if heads > 1 else []
    a = c.inp("A", hdims + [m, k], in_dtype)
    b = c.inp("B", hdims + [k, n], in_dtype)
    o = c.out("C", hdims + [m, n], acc_dtype)
    hidx = [v("h")] if heads > 1 else []
    mac = Compute(
        "MAC",
        ref(o, *hidx, v("m"), v("n")),
        (ref(a, *hidx, v("m"), v("k")), ref(b, *hidx, v("k"), v("n")),
         ref(o, *hidx, v("m"), v("n"))),
        roles={"m": ["m"], "n": ["n"], "k": ["k"]},
        dtype=dt(acc_dtype),
    )
    nest = Loop("m", 0, m, 1, [Loop("n", 0, n, 1, [Loop("k", 0, k, 1, [mac])])])
    if heads > 1:
        nest = Loop("h", 0, heads, 1, [nest])
    c.body.append(nest)

    def oracle(inputs, _acc=dt(acc_dtype)):
        a64 = np.asarray(inputs["A"]).astype(np.int64 if _acc.kind != "float" else np.float64)
        b64 = np.asarray(inputs["B"]).astype(a64.dtype)
        return {"C": (a64 @ b64).astype(_acc.np)}

    c.oracle = oracle
    return c


def fc(cin: int, cout: int, batch: int = 1, name: str | None = None) -> Codelet:
    return gemm(batch, cout, cin, name=name or f"fc_{cin}x{cout}")


def conv2d(n: int, ih: int, iw: int, ic: int, oc: int, kh: int, kw: int,
           stride: int = 1, name: str | None = None) -> Codelet:
    """Direct convolution; output spatial dims derived from stride (VALID)."""
    oh = (ih - kh) // stride + 1
    ow = (iw - kw) // stride + 1
    c = Codelet(name or f"conv_{ih}x{iw}x{ic}_{oc}k{kh}s{stride}")
    for pname, val in (("N", n), ("IH", ih), ("IW", iw), ("IC", ic), ("OC", oc),
                       ("KH", kh), ("KW", kw), ("S", stride)):
        c.param(pname, val)
    x = c.inp("X", [n, ih, iw, ic], "i8")
    w = c.inp("W", [kh, kw, ic, oc], "i8")
    o = c.out("O", [n, oh, ow, oc], "i32")
    mac = Compute(
        "MAC",
        ref(o, v("b"), v("oh"), v("ow"), v("oc")),
        (
            ref(x, v("b"), v("oh") * stride + v("kh"), v("ow") * stride + v("kw"), v("ic")),
            ref(w, v("kh"), v("kw"), v("ic"), v("oc")),
            ref(o, v("b"), v("oh"), v("ow"), v("oc")),
        ),
        roles={"m": ["b", "oh", "ow"], "n": ["oc"], "k": ["kh", "kw", "ic"]},
        dtype=dt("i32"),
    )
    nest = mac
    for var, ub in (("ic", ic), ("kw", kw), ("kh", kh), ("oc", oc),
                    ("ow", ow), ("oh", oh), ("b", n)):
        nest = Loop(var, 0, ub, 1, [nest])
    c.body.append(nest)

    def oracle(inputs, _oh=oh, _ow=ow, _s=stride):
        x = np.asarray(inputs["X"]).astype(np.int64)
        w = np.asarray(inputs["W"]).astype(np.int64)
        nb, _, _, _ = x.shape
        khh, kww, icc, occ = w.shape
        out = np.zeros((nb, _oh, _ow, occ), dtype=np.int64)
        for i in range(khh):
            for j in range(kww):
                patch = x[:, i:i + _s * _oh:_s, j:j + _s * _ow:_s, :]
                out += np.einsum("bhwc,co->bhwo", patch, w[i, j])
        return {"O": out.astype(np.int32)}

    c.oracle = oracle
    return c


def relu(n: int, dtype: str = "i32") -> Codelet:
    return elementwise("RELU", n, dtype, arity=1)


# ---------------------------------------------------------------------------
# Table 2 — the paper's benchmark layer set
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class LayerSpec:
    model: str
    layer: str
    build: object  # () -> Codelet

    @property
    def key(self) -> str:
        return f"{self.model}-{self.layer}"


def _bert(layer: str, m, n, k, heads=1):
    return LayerSpec("BERT-LG", layer,
                     lambda: gemm(m, n, k, heads=heads, name=f"bert_{layer.lower()}"))


PAPER_LAYERS: list[LayerSpec] = [
    # BERT-Large, sequence length 384 (Table 2 rows 1-6)
    _bert("GEMM1", 384, 4096, 1024),
    _bert("GEMM2", 384, 1024, 4096),
    _bert("ATN1-GEMM", 384, 64, 1024, heads=16),
    _bert("ATN2-GEMM", 384, 384, 64, heads=16),
    _bert("ATN3-GEMM", 384, 64, 384, heads=16),
    _bert("ATN4-GEMM", 384, 1024, 1024),
    # DLRM MLP stack (batch 1)
    LayerSpec("DLRM", "FC1", lambda: fc(745, 367, name="dlrm_fc1")),
    LayerSpec("DLRM", "FC2", lambda: fc(367, 512, name="dlrm_fc2")),
    LayerSpec("DLRM", "FC3", lambda: fc(512, 256, name="dlrm_fc3")),
    LayerSpec("DLRM", "FC4", lambda: fc(256, 1, name="dlrm_fc4")),
    # CNNs
    LayerSpec("InceptionV3", "FC1", lambda: fc(2048, 1000, name="incep_fc1")),
    LayerSpec("InceptionV3", "CONV1",
              lambda: conv2d(1, 299, 299, 3, 32, 3, 3, 2, name="incep_conv1")),
    LayerSpec("MobileNetV3", "CONV1",
              lambda: conv2d(1, 224, 224, 3, 16, 3, 3, 2, name="mbnet_conv1")),
    LayerSpec("MobileNetV3", "CONV2",
              lambda: conv2d(1, 112, 112, 16, 64, 3, 3, 1, name="mbnet_conv2")),
    LayerSpec("ResNet50", "FC1", lambda: fc(512, 1000, name="resnet_fc1")),
    LayerSpec("ResNet50", "CONV1",
              lambda: conv2d(1, 224, 224, 3, 64, 7, 7, 2, name="resnet_conv1")),
    LayerSpec("ResNet50", "CONV2",
              lambda: conv2d(1, 224, 224, 64, 64, 3, 3, 4, name="resnet_conv2")),
]


def paper_layer(key: str) -> Codelet:
    for spec in PAPER_LAYERS:
        if spec.key == key:
            return spec.build()
    raise KeyError(f"unknown paper layer {key!r}; known: {[s.key for s in PAPER_LAYERS]}")


__all__ = ["PAPER_LAYERS", "LayerSpec", "conv2d", "elementwise", "fc", "gemm",
           "paper_layer", "relu"]
