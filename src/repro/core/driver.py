"""``repro.compile`` — the one-call Covenant compile driver.

Everything the examples, benchmarks and tests used to hand-stitch
(``library.* -> scheduler.schedule -> codegen.generate -> stream.run_stream
-> cost.cost``, each with its own loose knobs) behind a single entry point:

    art = repro.compile(library.gemm(16, 32, 24), target="hvx")
    art.run({"A": A, "B": B})     # execute the mnemonic stream
    art.cycles()                  # analytic cycle count
    art.listing(5)                # mnemonic listing
    art.verify({"A": A, "B": B})  # stream outputs == numpy oracle

Design points:

* **Target registry** — ``target`` is a registry name (``repro.targets``:
  bundled covenant specs plus ``register``-ed ones, including derived
  variants like ``"dnnweaver@pe=32x32"``), an ``ACGSpec``, or an ACG
  instance; per-ACG pass hooks (``acg.pass_overrides`` /
  ``acg.extra_passes``) are applied to the stock pipeline automatically,
  so bringing your own codegen is attribute-plus-hook work, never a
  compiler fork.
* **Content-addressed cache** — artifacts are keyed by (codelet fingerprint,
  ACG fingerprint, options fingerprint, pipeline fingerprint); a repeated
  ``compile`` of the same inputs returns the *same artifact object* without
  re-running any pass.  ``compile_many`` batches sweeps over the cache.
* **Lazy analytics** — scheduling runs eagerly (it is what a compile *is*),
  but mnemonic expansion (``codegen``) is deferred until ``.program`` /
  ``.run()`` / ``.listing()`` is first touched: Table-2-scale layers exceed
  the full-unroll stream budget and are served by the analytic model alone.
"""
from __future__ import annotations

import dataclasses
import hashlib
from typing import Iterable, Sequence

import numpy as np

from . import cost as cost_mod
from . import library as library_mod
from . import spec as spec_mod
from . import store as store_mod
from . import stream as stream_mod
from . import targets as targets_mod
from .acg import ACG
from .codelet import Codelet
from .pipeline import CompileOptions, PassContext, Pipeline
from .search import SearchOptions, SearchResult, search_schedule
from .store import ArtifactStore

# ---------------------------------------------------------------------------
# fingerprints (content addressing)
# ---------------------------------------------------------------------------


def _sha(*parts: str) -> str:
    h = hashlib.sha256()
    for p in parts:
        h.update(p.encode())
        h.update(b"\x00")
    return h.hexdigest()


def codelet_fingerprint(cdlt: Codelet) -> str:
    """Content hash of a codelet: name, body (loops/refs), surrogate
    shapes+dtypes, and param values (which the pretty-printer omits)."""
    params = ",".join(f"{s.name}={s.value}" for s in cdlt.surrogates.values()
                      if s.kind == "param")
    return _sha(cdlt.name, str(cdlt), params)


def acg_fingerprint(acg: ACG) -> str:
    """Content hash of a target: the canonical covenant-spec fingerprint
    (``acg.to_spec().fingerprint()``).  Unlike the old describe()-based
    hash this covers mnemonic *field layouts* too, so two in-memory ACGs
    sharing a name can never alias in the cache or the artifact store, and
    a mutated ACG re-fingerprints to a fresh key instead of collecting a
    stale warm hit."""
    return acg.to_spec().fingerprint()


def compile_key(codelet_or_layer, target, options: CompileOptions | None
                = None, pipeline: Pipeline | None = None) -> str:
    """The content-addressed key ``compile(...)`` would file this compile
    under, *without compiling* — the work-unit identity of the sweep
    coordinator (``core/sweep.py``): coordinators dedup against the
    store and partition work by this key before any worker runs."""
    cdlt = _resolve_codelet(codelet_or_layer)
    acg, acg_fp = _resolve_target(target)
    opts = options if options is not None else CompileOptions()
    pl = pipeline if pipeline is not None \
        else Pipeline.default().with_acg_hooks(acg)
    return _sha(codelet_fingerprint(cdlt), acg_fp,
                opts.fingerprint(), pl.fingerprint())


# ---------------------------------------------------------------------------
# compiled artifact
# ---------------------------------------------------------------------------


@dataclasses.dataclass(eq=False)
class CompiledArtifact:
    """A finished compile: scheduled codelet + lazy program and analytics.

    An artifact restored from a disk ``ArtifactStore`` starts with *no*
    pipeline stage executed: its cost reports and schedule decisions come
    from the stored entry, and the scheduled codelet is rebuilt lazily
    (``_ensure_scheduled``) by replaying the pipeline with the stored
    tiling/unroll injected — only when ``.program`` / ``.run()`` or an
    unstored analytic is actually touched.
    """

    codelet: Codelet            # the scheduled (transformed) codelet
    acg: ACG
    options: CompileOptions
    target: str                 # target name (acg.name for ACG instances)
    key: str                    # content-addressed cache key
    pipeline: Pipeline
    ctx: PassContext            # pass state (plans, tiling, pack, program)
    search: SearchResult | None = None   # attached when compiled via search

    # -- lazy schedule replay (store restores) -------------------------------
    def _ensure_scheduled(self) -> None:
        """Replay the scheduling stages if none ran yet (artifact was
        restored from the disk store; ``ctx.overrides`` carries the stored
        schedule decisions, so no tiling search/enumeration re-runs)."""
        if not self.ctx.executed:
            self.pipeline.run(self.ctx, skip=("codegen",))

    # -- program (lazy mnemonic expansion) -----------------------------------
    @property
    def program(self):
        """The macro-mnemonic stream; generated on first access.  Raises
        ``codegen.StreamTooLarge`` for layers past ``options.max_mnemonics``
        (use the analytic ``.cycles()`` / ``.report()`` for those)."""
        if "program" not in self.ctx.state:
            self._ensure_scheduled()
            self.pipeline.run_stage("codegen", self.ctx)
        return self.ctx.state["program"]

    @property
    def mnemonics(self) -> list:
        return self.program.mnemonics

    def listing(self, limit: int = 50) -> str:
        return self.program.listing(limit)

    # -- execution -----------------------------------------------------------
    def _default_pack(self) -> bool:
        # the pipeline's "pack" stage records the decision (a target override
        # may have changed it); fall back to the raw option if it never ran
        return self.ctx.state.get("pack", self.options.pack)

    def run(self, inputs: dict, pack: bool | None = None):
        """Execute the mnemonic stream on the stream machine; returns a
        ``stream.StreamResult`` (outputs + serial/packed cycle counts)."""
        if pack is None:
            pack = self._default_pack()
        return stream_mod.run_stream(self.program, inputs, pack=pack)

    def verify(self, oracle_inputs: dict, atol: float = 1e-5) -> bool:
        """Stream-machine outputs equal the codelet's numpy oracle?"""
        assert self.codelet.oracle is not None, \
            f"codelet {self.codelet.name} carries no oracle"
        want = self.codelet.oracle(oracle_inputs)
        got = self.run(oracle_inputs).outputs
        for k, w in want.items():
            g = got[k]
            if np.issubdtype(np.asarray(w).dtype, np.floating):
                if not np.allclose(g, w, atol=atol):
                    return False
            elif not np.array_equal(g, w):
                return False
        return True

    # -- analytics (no stream needed) ----------------------------------------
    def report(self, pack: bool | None = None) -> "cost_mod.CostReport":
        if pack is None:
            pack = self._default_pack()
        cached = self.ctx.state.get(("report", pack))
        if cached is None:
            self._ensure_scheduled()
            cached = cost_mod.cost(self.codelet, self.acg, pack=pack)
            self.ctx.state[("report", pack)] = cached
        return cached

    def cycles(self, pack: bool | None = None) -> float:
        return self.report(pack=pack).cycles

    @property
    def schedule_notes(self) -> list[str]:
        # store-restored artifacts report the original compile's notes,
        # stable across the lazy replay (the replayed codelet's own notes
        # stay reachable via ``art.codelet.schedule_notes``)
        stored = self.ctx.state.get("schedule_notes")
        if stored is not None:
            return list(stored)
        return self.codelet.schedule_notes

    def __repr__(self) -> str:
        return (f"CompiledArtifact({self.codelet.name} @ {self.target}, "
                f"stages={self.ctx.executed}, key={self.key[:12]})")


# ---------------------------------------------------------------------------
# target registry
# ---------------------------------------------------------------------------


def register_target(name: str, factory, *, pass_overrides: dict | None = None,
                    extra_passes: Sequence[tuple] | None = None) -> None:
    """Register an ACG factory under ``name`` (usable as ``compile(...,
    target=name)``).  Optional hooks are attached to every instance the
    factory produces — the BYOC extension point."""
    if pass_overrides or extra_passes:
        base = factory

        def factory():
            acg = base()
            acg.pass_overrides.update(pass_overrides or {})
            for entry in extra_passes or ():
                # idempotent even when the user's factory returns a shared
                # ACG instance: never splice the same pass twice
                if entry not in acg.extra_passes:
                    acg.extra_passes.append(entry)
            return acg

    targets_mod.TARGETS[name] = factory
    _TARGETS_RESOLVED.pop(name, None)


def available_targets() -> list[str]:
    return targets_mod.list_targets()


# name -> (factory, acg, pristine_fingerprint): building a full ACG (graph
# + mnemonic vocabulary) costs ~0.5ms — pointless on every cache hit of a
# sweep, so resolved names (incl. derived variants) memoise the built
# graph.  The factory identity is stored so that direct mutation of
# targets.TARGETS (the registry's public idiom) invalidates the entry; the
# fingerprint taken at build time is stored so that mutation of the shared
# instance is *detected* on the next resolve — a registered name always
# compiles the architecture it was registered as, never a drifted copy —
# by re-fingerprinting the live instance every time.
_TARGETS_RESOLVED: dict[str, tuple[object, ACG, str]] = {}
# spec fingerprint -> built ACG.  The spec is frozen so the *build* is
# memoisable (keyed by fingerprint, not the object: attrs may hold
# unhashable values), but the built graph is a live, mutable object — its
# fingerprint is recomputed per resolve, exactly like the name path, so a
# caller mutating the shared instance never rides a stale key.
_SPECS_RESOLVED: dict[str, ACG] = {}


def _resolve_target(target) -> tuple[ACG, str]:
    """-> (acg, acg_fingerprint).  ``target`` may be a registry name
    (including a ``base@key=value`` derived-variant name), an ``ACGSpec``,
    or an ACG instance."""
    if isinstance(target, ACG):
        return target, acg_fingerprint(target)
    if isinstance(target, spec_mod.ACGSpec):
        fp = target.fingerprint()
        acg = _SPECS_RESOLVED.get(fp)
        if acg is None or acg_fingerprint(acg) != fp:
            # miss, or the shared instance was mutated away from its spec:
            # rebuild so a pristine spec always compiles a faithful graph
            acg = _SPECS_RESOLVED[fp] = ACG.from_spec(target)
        return acg, fp
    if isinstance(target, str):
        # memo-invalidation identity shares targets.resolve_factory's
        # one rule (exact registered name wins over the base)
        factory = targets_mod.resolve_factory(target)
        cached = _TARGETS_RESOLVED.get(target)
        if cached is None or cached[0] is not factory \
                or acg_fingerprint(cached[1]) != cached[2]:
            acg = targets_mod.get_target(target)  # KeyError for unknown
            cached = (factory, acg, acg_fingerprint(acg))
            _TARGETS_RESOLVED[target] = cached
        return cached[1], cached[2]
    raise TypeError(
        f"target must be a name, an ACGSpec or an ACG, got {type(target)!r}")


def _resolve_codelet(obj) -> Codelet:
    if isinstance(obj, Codelet):
        return obj
    if isinstance(obj, library_mod.LayerSpec):
        return obj.build()
    if isinstance(obj, str):
        return library_mod.paper_layer(obj)
    build = getattr(obj, "build", None)
    if callable(build):  # LayerSpec-shaped records (e.g. launch LayerGemm)
        built = build()
        if isinstance(built, Codelet):
            return built
    if callable(obj):  # layer builder thunk
        built = obj()
        if isinstance(built, Codelet):
            return built
    raise TypeError(
        f"expected a Codelet, LayerSpec, paper-layer key or builder; "
        f"got {type(obj)!r}")


# ---------------------------------------------------------------------------
# the compile cache
# ---------------------------------------------------------------------------

# Two tiers share the content-addressed keys: the in-process dict below
# (unbounded — the working set is the sweep itself) and, when configured,
# a disk-backed size-bounded ``ArtifactStore`` (``CompileOptions(store=...)``
# or the REPRO_CACHE_DIR environment variable) that lets a *fresh process*
# replay sweeps and tuned schedules without re-running scheduling or search.
_CACHE: dict[str, CompiledArtifact] = {}
_STATS = {"hits": 0, "misses": 0, "store_hits": 0, "store_misses": 0}


def clear_cache(disk: bool = False, store=None) -> None:
    """Empty the in-process cache; ``disk=True`` also empties the disk
    store (``store`` argument, else the REPRO_CACHE_DIR default)."""
    _CACHE.clear()
    # target-resolution memos grow one built ACG per distinct variant name
    # / spec; a cache clear is the documented reset point between sweeps
    _TARGETS_RESOLVED.clear()
    _SPECS_RESOLVED.clear()
    for k in _STATS:
        _STATS[k] = 0
    if disk:
        st = store_mod.resolve(store)
        if st is not None:
            st.clear()


def cache_stats() -> dict:
    return dict(_STATS, size=len(_CACHE))


def _restore_from_store(entry: dict, cdlt: Codelet, acg: ACG,
                        opts: CompileOptions, pl: Pipeline,
                        key: str) -> CompiledArtifact:
    """Rebuild an artifact from a stored entry with ZERO pass executions:
    analytics come from the stored reports, the schedule decisions become
    ``ctx.overrides`` so any later ``.program`` touch replays them."""
    ctx = PassContext(cdlt.clone(), acg, opts)
    if entry.get("tiling") is not None:
        ctx.overrides["tiling"] = {str(k): int(v)
                                   for k, v in entry["tiling"].items()}
    ctx.overrides["unroll_factor"] = int(
        entry.get("unroll_factor", opts.unroll_factor))
    ctx.state["pack"] = bool(entry["pack"])
    ctx.state["schedule_notes"] = [str(n) for n in entry.get("notes", ())]
    for pack, rep in store_mod.reports_from_entry(entry).items():
        ctx.state[("report", pack)] = rep
    art = CompiledArtifact(codelet=ctx.cdlt, acg=acg, options=opts,
                           target=acg.name, key=key, pipeline=pl, ctx=ctx)
    s = entry.get("search")
    if s:
        art.search = SearchResult(
            best=ctx.cdlt, best_cycles=float(s["best_cycles"]),
            heuristic_cycles=float(s["heuristic_cycles"]),
            evaluated=int(s["evaluated"]),
            trace=[tuple(t) for t in s.get("trace", [])],
            strategy=s.get("strategy", "evolutionary"), point=s.get("point"),
            seeded=int(s.get("seeded", 0)), space_sig=s.get("space_sig"))
    return art


# ---------------------------------------------------------------------------
# entry points
# ---------------------------------------------------------------------------


def compile(codelet_or_layer, target="hvx",
            options: CompileOptions | None = None, *,
            pipeline: Pipeline | None = None,
            cache: bool = True) -> CompiledArtifact:
    """Compile a codelet (or paper-layer key / LayerSpec / builder) for a
    target, returning a cached ``CompiledArtifact``.

    ``target`` is a registry name — including a derived-variant name such
    as ``"dnnweaver@pe=32x32"`` (see ``repro.targets``) — an ``ACGSpec``,
    or an ACG instance.

    ``pipeline`` overrides the stock pass pipeline entirely; otherwise the
    default pipeline plus the target's ACG hooks is used.

    ``options.search`` routes the compile through schedule search (the
    winner — never worse than the heuristic — is the artifact, with the
    ``SearchResult`` trace attached as ``art.search``).  ``options.store``
    or ``REPRO_CACHE_DIR`` adds a disk tier: warm hits restore without
    executing any pipeline stage; ``cache=False`` bypasses both tiers.
    """
    cdlt = _resolve_codelet(codelet_or_layer)
    acg, acg_fp = _resolve_target(target)
    opts = options if options is not None else CompileOptions()
    pl = pipeline if pipeline is not None \
        else Pipeline.default().with_acg_hooks(acg)
    key = _sha(codelet_fingerprint(cdlt), acg_fp,
               opts.fingerprint(), pl.fingerprint())
    store = store_mod.resolve(opts.store) if cache else None
    if cache and key in _CACHE:
        _STATS["hits"] += 1
        art = _CACHE[key]
        if store is not None and key not in store:
            # the key was compiled before this store was configured —
            # backfill so a fresh process still replays it warm
            try:
                store.put(key, store_mod.entry_from_artifact(art))
            except Exception:
                pass  # persistence is opportunistic, never fatal
        return art
    _STATS["misses"] += 1
    if store is not None:
        entry = store.load(key)
        if entry is not None:
            try:
                art = _restore_from_store(entry, cdlt, acg, opts, pl, key)
            except Exception:
                # entry parsed but is unusable (schema drift): drop it and
                # recompile cleanly below
                store.invalidate(key)
                art = None
            if art is not None:
                _STATS["store_hits"] += 1
                _CACHE[key] = art
                return art
        _STATS["store_misses"] += 1
    if opts.search is not None:
        # the resolved store doubles as the warm-start measurement
        # database (SearchOptions(warm_start=True))
        res = search_schedule(cdlt, acg, options=opts, pipeline=pl,
                              store=store)
        ctx = res.best_ctx
        art = CompiledArtifact(codelet=ctx.cdlt, acg=acg, options=opts,
                               target=acg.name, key=key, pipeline=pl,
                               ctx=ctx, search=res)
    else:
        ctx = PassContext(cdlt.clone(), acg, opts)
        pl.run(ctx, skip=("codegen",))  # codegen deferred to .program
        art = CompiledArtifact(codelet=ctx.cdlt, acg=acg, options=opts,
                               target=acg.name, key=key, pipeline=pl,
                               ctx=ctx)
    if cache:
        _CACHE[key] = art
    if store is not None:
        try:
            store.put(key, store_mod.entry_from_artifact(art))
        except Exception:
            pass  # a full/read-only/unserialisable store entry must never
            #       fail an otherwise-successful compile
    return art


def _parallel_prefill(items: list, target, options: CompileOptions | None,
                      workers: int) -> None:
    """Back half of ``compile_many(parallel=N)``: compile the batch's
    still-cold, process-portable units in N worker processes *through the
    shared artifact store*, so the in-order sequential pass that follows
    restores every one of them warm (zero pipeline stages) and returns
    real ``CompiledArtifact`` objects from this process's cache tiers."""
    from . import sweep as sweep_mod

    store = store_mod.resolve(options.store if options is not None else None)
    if store is None:
        import warnings
        warnings.warn(
            "compile_many(parallel=...) needs a shared disk store "
            "(CompileOptions(store=...) or REPRO_CACHE_DIR) to hand "
            "results back; compiling sequentially instead")
        return
    opts = options if options is not None else CompileOptions()
    base = dataclasses.replace(opts, store=None)
    units: dict[str, "sweep_mod.WorkUnit"] = {}
    for item in items:
        if isinstance(item, tuple) and len(item) == 2:
            it, tgt = item
        else:
            it, tgt = item, target
        if not isinstance(tgt, str):
            continue  # live ACG/spec targets stay in-process
        try:
            workload = sweep_mod.workload_of(it)
        except TypeError:
            continue
        if workload[0] == "local":
            continue  # raw codelets cannot cross a process boundary
        key = compile_key(sweep_mod.build_workload(workload), tgt, base)
        if key in _CACHE or key in store:
            continue
        units.setdefault(key, sweep_mod.WorkUnit(
            layer=sweep_mod._workload_label(workload), target=tgt,
            workload=workload, options=base, key=key))
    if not units:
        return
    todo = sorted(units.values(), key=lambda u: u.key)
    sweep_mod._process_backend(sweep_mod.partition(todo, workers), store,
                               sweep_mod.plan_id(todo))


def compile_many(items: Iterable, target="hvx",
                 options: CompileOptions | None = None, *,
                 parallel: int | None = None,
                 **kwargs) -> list[CompiledArtifact]:
    """Batch compile: one artifact per item, in order, sharing the cache.

    ``items`` may mix Codelets, LayerSpecs, paper-layer keys and builders.
    An item may also be a ``(codelet, target)`` pair, overriding the
    sweep-wide ``target`` for that item — one batched sweep can span
    several architecture variants::

        repro.compile_many([
            ("DLRM-FC1", "dnnweaver"),
            ("DLRM-FC1", "dnnweaver@pe=32x32"),
            "DLRM-FC2",                          # uses ``target``
        ], target="hvx")

    ``parallel=N`` (with a disk store configured) fans the cold units of
    the batch out across N worker processes first — the ``core/sweep.py``
    process backend over the shared ``ArtifactStore`` — then the ordered
    results below are pure warm restores.  Items the coordinator cannot
    ship to a worker (raw Codelets, live ACG targets, custom pipelines)
    simply compile sequentially here, same semantics, one process."""
    items = list(items)
    if parallel is not None and int(parallel) > 1 \
            and kwargs.get("cache", True) \
            and kwargs.get("pipeline") is None:
        _parallel_prefill(items, target, options, int(parallel))
    arts = []
    for item in items:
        if isinstance(item, tuple) and len(item) == 2:
            it, tgt = item
        else:
            it, tgt = item, target
        arts.append(compile(it, tgt, options, **kwargs))
    return arts


__all__ = ["ArtifactStore", "CompileOptions", "CompiledArtifact",
           "SearchOptions", "SearchResult", "acg_fingerprint",
           "available_targets", "cache_stats", "clear_cache",
           "codelet_fingerprint", "compile", "compile_key", "compile_many",
           "register_target"]
