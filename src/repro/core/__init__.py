"""Covenant compiler core — the paper's contribution.

Pipeline: ``library`` Codelets -> named pass pipeline (``pipeline``:
placement, compute mapping, Algorithm-1 tiling, transfer insertion,
vectorize / unroll / pack, macro-mnemonic ``codegen``) -> ``stream``
execution, with ``interp`` (functional) and ``cost`` (analytic cycles) as
cross-checks.  ``targets`` holds the predefined ACGs; ``driver`` is the
user-facing ``repro.compile()`` entry point with the content-addressed
compile cache, schedule ``search`` (a strategy registry materialising
candidates through the pipeline) and the disk-backed ``store``.
``scheduler.schedule`` / ``codegen.generate`` remain as thin stable
wrappers over the pipeline stages.
"""
from . import (acg, codegen, codelet, cost, covenant, driver, dtypes, interp,
               library, passes, pipeline, scheduler, search, semantics, spec,
               store, stream, targets)
from .acg import ACG, Capability, ComputeNode, Edge, MemoryNode, cap, ospec
from .codelet import Codelet, Compute, Loop, Ref, Surrogate, Transfer, ref, v
from .covenant import (CovenantError, CovenantViolation, check_covenant,
                       validate_acg)
from .driver import (CompiledArtifact, available_targets, cache_stats,
                     clear_cache, compile, compile_many, register_target)
from .dtypes import Dtype, dt
from .pipeline import CompileOptions, PassContext, Pipeline, PipelineError
from .scheduler import ScheduleConfig, schedule
from .search import SearchOptions, SearchResult
from .spec import ACGSpec, SpecError, acg_spec, validate_spec
from .store import ArtifactStore
from .targets import get_spec, get_target, list_targets, register_spec

__all__ = [
    "ACG", "ACGSpec", "ArtifactStore", "Capability", "Codelet",
    "CompileOptions", "CompiledArtifact", "Compute", "ComputeNode",
    "CovenantError", "CovenantViolation", "Dtype", "Edge", "Loop",
    "MemoryNode", "PassContext", "Pipeline", "PipelineError", "Ref",
    "ScheduleConfig", "SearchOptions", "SearchResult", "SpecError",
    "Surrogate", "Transfer", "acg", "acg_spec", "available_targets",
    "cache_stats", "cap", "check_covenant", "clear_cache", "codegen",
    "codelet", "compile", "compile_many", "cost", "covenant", "driver",
    "dt", "dtypes", "get_spec", "get_target", "interp", "library",
    "list_targets", "ospec", "passes", "pipeline", "ref", "register_spec",
    "register_target", "schedule", "scheduler", "search", "semantics",
    "spec", "store", "stream", "targets", "v", "validate_acg",
    "validate_spec",
]
