"""Covenant compiler core — the paper's contribution.

Pipeline: ``library`` Codelets -> ``scheduler.schedule`` (placement, compute
mapping, Algorithm-1 tiling, transfer insertion) -> ``passes`` optimizations
(vectorize / unroll / pack) -> ``codegen.generate`` macro-mnemonic expansion
-> ``stream.run_stream`` execution, with ``interp`` (functional) and ``cost``
(analytic cycles) as cross-checks.  ``targets`` holds the predefined ACGs.
"""
from . import (acg, codegen, codelet, cost, dtypes, interp, library, passes,
               scheduler, semantics, stream, targets)
from .acg import ACG, Capability, ComputeNode, Edge, MemoryNode, cap, ospec
from .codelet import Codelet, Compute, Loop, Ref, Surrogate, Transfer, ref, v
from .dtypes import Dtype, dt
from .scheduler import ScheduleConfig, schedule
from .targets import get_target

__all__ = [
    "ACG", "Capability", "Codelet", "Compute", "ComputeNode", "Dtype",
    "Edge", "Loop", "MemoryNode", "Ref", "ScheduleConfig", "Surrogate",
    "Transfer", "acg", "cap", "codegen", "codelet", "cost", "dt", "dtypes",
    "get_target", "interp", "library", "ospec", "passes", "ref", "schedule",
    "scheduler", "semantics", "stream", "targets", "v",
]
