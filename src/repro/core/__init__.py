"""Covenant compiler core — the paper's contribution.

Pipeline: ``library`` Codelets -> named pass pipeline (``pipeline``:
placement, compute mapping, Algorithm-1 tiling, transfer insertion,
vectorize / unroll / pack, macro-mnemonic ``codegen``) -> ``stream``
execution, with ``interp`` (functional) and ``cost`` (analytic cycles) as
cross-checks.  ``targets`` holds the predefined ACGs; ``driver`` is the
user-facing ``repro.compile()`` entry point with the content-addressed
compile cache, schedule ``search`` (a strategy registry materialising
candidates through the pipeline) and the disk-backed ``store``.
``scheduler.schedule`` / ``codegen.generate`` remain as thin stable
wrappers over the pipeline stages.
"""
from . import (acg, codegen, codelet, cost, driver, dtypes, interp, library,
               passes, pipeline, scheduler, search, semantics, store, stream,
               targets)
from .acg import ACG, Capability, ComputeNode, Edge, MemoryNode, cap, ospec
from .codelet import Codelet, Compute, Loop, Ref, Surrogate, Transfer, ref, v
from .driver import (CompiledArtifact, available_targets, cache_stats,
                     clear_cache, compile, compile_many, register_target)
from .dtypes import Dtype, dt
from .pipeline import CompileOptions, PassContext, Pipeline
from .scheduler import ScheduleConfig, schedule
from .search import SearchOptions, SearchResult
from .store import ArtifactStore
from .targets import get_target

__all__ = [
    "ACG", "ArtifactStore", "Capability", "Codelet", "CompileOptions",
    "CompiledArtifact", "Compute", "ComputeNode", "Dtype", "Edge", "Loop",
    "MemoryNode", "PassContext", "Pipeline", "Ref", "ScheduleConfig",
    "SearchOptions", "SearchResult", "Surrogate", "Transfer", "acg",
    "available_targets", "cache_stats", "cap", "clear_cache", "codegen",
    "codelet", "compile", "compile_many", "cost", "driver", "dt", "dtypes",
    "get_target", "interp", "library", "ospec", "passes", "pipeline", "ref",
    "register_target", "schedule", "scheduler", "search", "semantics",
    "store", "stream", "targets", "v",
]
