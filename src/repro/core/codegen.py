"""Macro-mnemonics — code generation from scheduled Codelets (§3.3).

The Covenant compiler "ensures valid code generation by combining operation
types, operand types, and their ACG node attributes to select pre-defined
functions for generating sequences of mnemonics called macro-mnemonics".

This module implements exactly that: a registry keyed by
``(operation_type, acg_node_selector)`` whose entries are functions
``(op, ctx) -> list[Mnemonic]``.  The default macros cover every paper
target; a new accelerator only needs new ACG attributes (and, rarely, a
specialised macro) — the *generator* itself is retargetable because
mnemonics are semantics-free (§2.1.4).

Generated streams are fully unrolled (loop iterations enumerated), with
per-iteration ``LOOPI`` bookkeeping mnemonics on targets without hardware
loop sequencers, so the stream simulator charges the same control overhead
the analytic model does.  Full unrolling is only tractable for small layers;
``generate`` raises past ``max_mnemonics`` and the analytic model
(``cost.py`` — mnemonic-faithful by construction) covers the big ones.

Every mnemonic instance carries:
* encoded fields (tested to round-trip through ``Mnemonic.encode``),
* ``rd``/``wr`` byte-interval descriptors for §4 packing dependency analysis,
* a ``sem`` descriptor the stream machine executes (decoded field view).
"""
from __future__ import annotations

import dataclasses
import math
from typing import Callable

import numpy as np

from .acg import ACG, Mnemonic
from .codelet import Aff, Codelet, Compute, Loop, Ref, Surrogate, Transfer

# ---------------------------------------------------------------------------
# memory map: bump allocation per ACG memory node
# ---------------------------------------------------------------------------


class StreamTooLarge(RuntimeError):
    pass


@dataclasses.dataclass
class Placement:
    node: str
    addr: int          # byte address within the node
    shape: tuple[int, ...]
    itemsize: int      # simulator byte width (dtype.np.itemsize)

    @property
    def nbytes(self) -> int:
        return math.prod(self.shape) * self.itemsize

    def strides(self) -> tuple[int, ...]:
        """Row-major element strides."""
        out, acc = [], 1
        for d in reversed(self.shape):
            out.append(acc)
            acc *= d
        return tuple(reversed(out))


class MemoryMap:
    """Assigns every surrogate a base byte address in its ACG location.

    Addresses are aligned to the node's ``data_width`` (Algorithm 1's
    addressability unit).  Off-chip/home nodes may exceed their declared
    capacity (the home holds whole operands; capacity constrains *staging*).
    """

    def __init__(self, acg: ACG):
        self.acg = acg
        self.cursor: dict[str, int] = {m.name: 0 for m in acg.memory_nodes()}
        self.places: dict[str, Placement] = {}

    def place(self, s: Surrogate) -> Placement:
        if s.name in self.places:
            return self.places[s.name]
        assert s.loc is not None and s.shape is not None and s.dtype is not None
        mem = self.acg.memory(s.loc)
        align = max(1, mem.data_width // 8)
        addr = math.ceil(self.cursor[s.loc] / align) * align
        p = Placement(s.loc, addr, s.shape, s.dtype.np.itemsize)
        self.cursor[s.loc] = addr + p.nbytes
        if not mem.offchip and self.cursor[s.loc] > mem.capacity_bytes:
            raise StreamTooLarge(
                f"{s.name}: staging overflows {s.loc} "
                f"({self.cursor[s.loc]} > {mem.capacity_bytes} bytes)")
        self.places[s.name] = p
        return p


# ---------------------------------------------------------------------------
# generation context
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class Program:
    """A generated mnemonic stream plus everything needed to execute it."""

    cdlt: Codelet
    acg: ACG
    memmap: MemoryMap
    mnemonics: list[Mnemonic]

    def __len__(self) -> int:
        return len(self.mnemonics)

    @property
    def bytes(self) -> int:
        return sum((m.mdef.bits + 7) // 8 for m in self.mnemonics)

    def listing(self, limit: int = 50) -> str:
        lines = [str(m) for m in self.mnemonics[:limit]]
        if len(self.mnemonics) > limit:
            lines.append(f"... (+{len(self.mnemonics) - limit} more)")
        return "\n".join(lines)


@dataclasses.dataclass
class Ctx:
    cdlt: Codelet
    acg: ACG
    memmap: MemoryMap
    env: dict[str, int]
    bounds: dict[str, int]  # loop var -> stop (for clamping)

    def placement(self, name: str) -> Placement:
        return self.memmap.place(self.cdlt.surrogates[name])

    def eval(self, ix: Aff) -> int:
        return ix.const + sum(c * self.env.get(var, 0) for var, c in ix.terms)


# ---------------------------------------------------------------------------
# transfer chunking — shared with the analytic cost model (cost.py imports it)
# ---------------------------------------------------------------------------


def xfer_chunks(rows: int, row_bits: int, coalesce: int, bandwidth: int
                ) -> tuple[int, int, int]:
    """2-D DMA burst plan: returns (n_chunks, rows_per_chunk, xfers_per_row).

    Without unrolling each XFER carries one contiguous row (Fig 8b: "Using
    only 25% of bandwidth!"); rows wider than the edge split; unrolling
    coalesces up to ``coalesce`` rows per burst, bounded by edge bandwidth
    (§4 Loop Unrolling).
    """
    row_bits = max(1, row_bits)
    if row_bits > bandwidth:
        per_row = math.ceil(row_bits / bandwidth)
        return rows * per_row, 1, per_row
    g = max(1, min(coalesce, bandwidth // row_bits))
    return math.ceil(rows / g), g, 1


# ---------------------------------------------------------------------------
# default macro-mnemonics
# ---------------------------------------------------------------------------


def _flat_rows(shape: tuple[int, ...]) -> tuple[int, int]:
    """(n_rows, row_elems) viewing an nd tile as rows of its last dim."""
    if not shape:
        return 1, 1
    return math.prod(shape[:-1]), shape[-1]


def _byte_off(place: Placement, idx: tuple[int, ...]) -> int:
    strides = place.strides()
    return place.addr + sum(i * st for i, st in zip(idx, strides)) * place.itemsize


def xfer_macro(t: Transfer, ctx: Ctx) -> list[Mnemonic]:
    """Expand one transfer op into ALLOC / XFER mnemonic sequences."""
    cdlt, acg = ctx.cdlt, ctx.acg
    out: list[Mnemonic] = []
    if t.dst_loc is not None and not t.src.var:
        # const-fill allocation (accumulator tile): one ALLOC, zero cycles —
        # systolic/SIMD units reset psums in-unit.
        s = cdlt.surrogates[t.alloc]
        p = ctx.placement(t.alloc)
        mdef = acg.mnemonics["ALLOC"]
        m = Mnemonic(mdef, {"NODE": p.node, "ADDR": p.addr, "SIZE": p.nbytes},
                     node=p.node, cycles=0)
        m.wr = [(p.node, p.addr, p.addr + p.nbytes)]
        m.rd = []
        m.sem = ("alloc", p, float(t.fill or 0), s.dtype.np)
        return [m]

    if t.dst_loc is not None:
        src_s = cdlt.surrogates[t.src.var]
        src_p = ctx.placement(t.src.var)
        dst_p = ctx.placement(t.alloc)
        src_start = [ctx.eval(ix) for ix in t.src.idx] or [0] * len(t.sizes)
        direction = (src_p.node, dst_p.node)
        dst_start = [0] * len(t.sizes)
    else:
        src_p = ctx.placement(t.src.var)
        dst_p = ctx.placement(t.dst.var)
        src_start = [0] * len(t.sizes)
        dst_start = [ctx.eval(ix) for ix in t.dst.idx] or [0] * len(t.sizes)
        direction = (src_p.node, dst_p.node)

    edge = acg.edge(*direction)
    itemsize = src_p.itemsize
    # clamp spans to both surrogate extents (trailing partial tiles)
    spans = [min(sz,
                 src_p.shape[d] - src_start[d],
                 dst_p.shape[d] - dst_start[d])
             for d, sz in enumerate(t.sizes)]
    if t.dst_loc is not None and any(sp < sz for sp, sz in zip(spans, t.sizes)):
        # partial tile: zero the staging buffer first so clamped compute
        # invocations reading past the span see zeros (interp semantics)
        s_loc = cdlt.surrogates[t.alloc]
        mz = Mnemonic(acg.mnemonics["ALLOC"],
                      {"NODE": dst_p.node, "ADDR": dst_p.addr,
                       "SIZE": dst_p.nbytes}, node=dst_p.node, cycles=0)
        mz.wr = [(dst_p.node, dst_p.addr, dst_p.addr + dst_p.nbytes)]
        mz.rd = []
        mz.sem = ("alloc", dst_p, 0.0, s_loc.dtype.np)
        out.append(mz)
    rows, row_elems = _flat_rows(tuple(spans))
    row_bytes = row_elems * itemsize
    coalesce = getattr(t, "coalesce", 1)
    n_chunks, g, per_row = xfer_chunks(rows, row_bytes * 8, coalesce,
                                       edge.bandwidth)
    mdef = acg.mnemonics["XFER"]

    # enumerate row start indices in the (possibly) nd span
    outer = spans[:-1] or [1]
    src_strides = src_p.strides()
    dst_strides = dst_p.strides()

    def row_addr(place, start, row_i, strides):
        idx = list(start)
        rem = row_i
        for d in range(len(outer) - 1, -1, -1):
            if len(spans) > 1:
                idx[d] = start[d] + rem % outer[d]
                rem //= outer[d]
        return _byte_off(place, tuple(idx))

    # rows are burstable in groups of g when consecutive rows are equidistant
    # in both source and destination (strided 2-D DMA)
    src_rstride = (src_strides[-2] * itemsize) if len(spans) > 1 else row_bytes
    dst_rstride = (dst_strides[-2] * itemsize) if len(spans) > 1 else row_bytes

    r = 0
    while r < rows:
        burst = min(g, rows - r)
        # only rows contiguous within the same innermost block may burst
        if len(spans) > 2 and burst > 1:
            per = outer[-1]
            burst = min(burst, per - ((r % per)))
        sa = row_addr(src_p, src_start, r, src_strides)
        da = row_addr(dst_p, dst_start, r, dst_strides)
        for piece in range(per_row):
            pb = min(row_bytes - piece * (edge.bandwidth // 8),
                     max(1, edge.bandwidth // 8))
            m = Mnemonic(mdef, {
                "SRC_NODE": src_p.node, "DST_NODE": dst_p.node,
                "SRC_ADDR": sa + piece * (edge.bandwidth // 8),
                "DST_ADDR": da + piece * (edge.bandwidth // 8),
                "ROWS": burst if per_row == 1 else 1,
                "ROW_BYTES": row_bytes if per_row == 1 else pb,
                "SRC_STRIDE": src_rstride, "DST_STRIDE": dst_rstride,
            }, node=dst_p.node, cycles=edge.latency)
            span_b = (burst - 1) * src_rstride + row_bytes if per_row == 1 else pb
            dspan_b = (burst - 1) * dst_rstride + row_bytes if per_row == 1 else pb
            m.rd = [(src_p.node, m.values["SRC_ADDR"], m.values["SRC_ADDR"] + span_b)]
            m.wr = [(dst_p.node, m.values["DST_ADDR"], m.values["DST_ADDR"] + dspan_b)]
            m.sem = ("xfer", src_p, dst_p, m.values, itemsize)
            out.append(m)
        r += burst
    return out


def _role_of(op: Compute) -> dict[str, str]:
    vec = getattr(op, "vec", {}) or {}
    role_of = {}
    for role, vars_ in op.roles.items():
        for var in vars_:
            if var in vec:
                role_of[var] = role
    return role_of


def _operand_view(r: Ref, ctx: Ctx, vec: dict[str, int], role_of) -> dict:
    """Decoded operand descriptor: base byte addr + labeled dims."""
    p = ctx.placement(r.var)
    strides = p.strides()
    base_idx, labels, shape, elem_strides = [], [], [], []
    for d, ix in enumerate(r.idx):
        base_idx.append(ctx.eval(ix))
        vt = [(var, c) for var, c in ix.terms if var in vec]
        if vt:
            var, c = vt[0]
            stop = ctx.bounds.get(var, 1 << 62)
            extent = max(1, min(vec[var], stop - ctx.env.get(var, 0)))
            # clamp by the surrogate extent along this dim (numpy-slice
            # semantics; covers unroll-shifted trailing invocations)
            step = max(1, abs(c))
            avail = max(1, -(-(p.shape[d] - base_idx[d]) // step))
            extent = min(extent, avail)
            labels.append(role_of.get(var, "n"))
            shape.append(extent)
            elem_strides.append(strides[d] * step)
    if not r.idx:
        base_idx = [0] * len(p.shape)
        labels = ["n"]
        shape = [math.prod(p.shape)]
        elem_strides = [1]
    return dict(place=p, base=_byte_off(p, tuple(base_idx)),
                labels="".join(labels), shape=tuple(shape),
                strides=tuple(elem_strides))


def compute_macro(op: Compute, ctx: Ctx) -> list[Mnemonic]:
    """One mnemonic per compute invocation, fields resolved from the ACG
    node the op was mapped to (the §3.3 contextual inputs)."""
    acg = ctx.acg
    cap = op.cap_obj
    vec = getattr(op, "vec", {}) or {}
    role_of = _role_of(op)
    node = acg.compute(op.loc)
    name = cap.name if cap.name in acg.mnemonics else op.capability
    mdef = acg.mnemonics[name]
    ins = [_operand_view(r, ctx, vec, role_of) for r in op.ins]
    outv = _operand_view(op.out, ctx, vec, role_of)

    def nbytes(view):
        if not view["shape"]:
            return view["place"].itemsize
        span = sum((s - 1) * st for s, st in zip(view["shape"], view["strides"]))
        return (span + 1) * view["place"].itemsize

    values: dict[str, object] = {}
    if cap.geometry is not None:  # matmul family
        dims = {"m": 1, "n": 1, "k": 1}
        for view in ins + [outv]:
            for lbl, extent in zip(view["labels"], view["shape"]):
                if lbl in dims:
                    dims[lbl] = max(dims[lbl], extent)
        a, b = ins[0], ins[1]
        accv = ins[2] if len(ins) > 2 else outv
        values = {
            "SRC1_ADDR": a["base"], "SRC2_ADDR": b["base"],
            "ACC_ADDR": accv["base"], "DST_ADDR": outv["base"],
            "M": dims["m"], "N": dims["n"], "K": dims["k"],
            "LD1": a["strides"][0] if a["strides"] else 1,
            "LD2": b["strides"][0] if b["strides"] else 1,
            "LDD": outv["strides"][0] if outv["strides"] else 1,
            "TGT": node.name,
        }
    else:
        n = outv["shape"][0] if outv["shape"] else 1
        values = {"DST_ADDR": outv["base"], "N": n, "TGT": node.name}
        values["SRC_ADDR" if len(ins) == 1 else "SRC1_ADDR"] = ins[0]["base"]
        if len(ins) > 1:
            values["SRC2_ADDR"] = ins[1]["base"]
    m = Mnemonic(mdef, values, node=node.name, cycles=cap.cycles)
    m.rd = [(v["place"].node, v["base"], v["base"] + nbytes(v)) for v in ins]
    m.wr = [(outv["place"].node, outv["base"], outv["base"] + nbytes(outv))]
    m.sem = ("compute", op.capability, ins, outv,
             op.dtype.np if op.dtype else np.int32)
    return [m]


def loopi_macro(level: int, trip: int, ctx: Ctx) -> list[Mnemonic]:
    if ctx.acg.loop_overhead <= 0:
        return []
    mdef = ctx.acg.mnemonics["LOOPI"]
    m = Mnemonic(mdef, {"LEVEL": level, "TRIP": trip}, node=None,
                 cycles=ctx.acg.loop_overhead)
    m.rd, m.wr = [], []
    m.sem = ("loopi",)
    return [m]


# registry — (operation type, node selector) -> macro.  "*" matches any node;
# targets can override entries for architecture-specific expansion.
MacroFn = Callable[..., list]
DEFAULT_MACROS: dict[tuple[str, str], MacroFn] = {
    ("transfer", "*"): xfer_macro,
    ("compute", "*"): compute_macro,
}


def select_macro(registry, op_type: str, node: str | None) -> MacroFn:
    if node is not None and (op_type, node) in registry:
        return registry[(op_type, node)]
    return registry[(op_type, "*")]


# ---------------------------------------------------------------------------
# generator entry point
# ---------------------------------------------------------------------------


def generate(cdlt: Codelet, acg: ACG, max_mnemonics: int = 300_000,
             macros: dict | None = None) -> Program:
    """Expand a scheduled codelet into a flat, executable mnemonic stream."""
    registry = dict(DEFAULT_MACROS)
    if macros:
        registry.update(macros)
    memmap = MemoryMap(acg)
    # place operands first (home), then locals (staging) in declaration order
    for s in cdlt.surrogates.values():
        if s.kind in ("inp", "out"):
            memmap.place(s)
    for s in cdlt.surrogates.values():
        if s.kind == "local":
            memmap.place(s)

    stream: list[Mnemonic] = []
    ctx = Ctx(cdlt, acg, memmap, {}, {})

    def emit(ms: list[Mnemonic]) -> None:
        stream.extend(ms)
        if len(stream) > max_mnemonics:
            raise StreamTooLarge(
                f"{cdlt.name}: stream exceeds {max_mnemonics} mnemonics; "
                "use the analytic cost model for this layer")

    def walk(body: list, depth: int) -> None:
        for item in body:
            if isinstance(item, Loop):
                ctx.bounds[item.var] = item.stop
                x, trip = item.start, 0
                while x < item.stop:
                    ctx.env[item.var] = x
                    emit(loopi_macro(depth, trip, ctx))
                    walk(item.body, depth + 1)
                    x += item.stride
                    trip += 1
                ctx.env.pop(item.var, None)
            elif isinstance(item, Transfer):
                node = item.dst_loc
                emit(select_macro(registry, "transfer", node)(item, ctx))
            elif isinstance(item, Compute):
                emit(select_macro(registry, "compute", item.loc)(item, ctx))

    walk(cdlt.body, 0)
    return Program(cdlt, acg, memmap, stream)


__all__ = ["DEFAULT_MACROS", "MemoryMap", "Placement", "Program",
           "StreamTooLarge", "compute_macro", "generate", "select_macro",
           "xfer_chunks", "xfer_macro"]
