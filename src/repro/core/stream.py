"""Stream machine: executes generated mnemonic streams byte-for-byte.

This is the reproduction's stand-in for the vendor cycle-accurate simulators
the paper measures with (Hexagon SDK simulator / DNNWeaver's open-source
simulator).  It owns the *semantics* of mnemonics — the compiler never does
(§2.1.4) — and provides two cycle counts:

* ``serial``  — one mnemonic at a time (sum of per-mnemonic cycles);
* ``packed``  — after VLIW packet formation (§4 Mnemonic Packing): greedy
  in-order packing with bounded hoisting, dependency analysis from the
  ``rd``/``wr`` byte intervals derived from field read/write annotations,
  and per-packet slot-class resources.

On targets with ``issue_slots == 1`` the two counts coincide.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np

from .acg import ACG, Mnemonic
from .codelet import Codelet
from .codegen import Placement, Program
from .semantics import MATMUL_FAMILY, apply_elementwise

# ---------------------------------------------------------------------------
# machine state
# ---------------------------------------------------------------------------


class Machine:
    """Byte-addressable storage per ACG memory node."""

    def __init__(self, program: Program):
        self.program = program
        acg = program.acg
        self.buffers: dict[str, np.ndarray] = {}
        for m in acg.memory_nodes():
            need = max(m.capacity_bytes, program.memmap.cursor.get(m.name, 0))
            self.buffers[m.name] = np.zeros(need, dtype=np.uint8)

    # -- typed views over raw bytes ----------------------------------------
    def view(self, node: str, base: int, shape, byte_strides, dtype) -> np.ndarray:
        buf = self.buffers[node]
        return np.ndarray(tuple(shape), dtype=dtype, buffer=buf,
                          offset=base, strides=tuple(byte_strides))

    def place_view(self, p: Placement, dtype) -> np.ndarray:
        strides = tuple(s * p.itemsize for s in p.strides())
        return self.view(p.node, p.addr, p.shape, strides, dtype)

    # -- I/O -----------------------------------------------------------------
    def load_inputs(self, inputs: dict[str, np.ndarray]) -> None:
        cdlt = self.program.cdlt
        for s in cdlt.surrogates.values():
            if s.kind != "inp":
                continue
            p = self.program.memmap.places[s.name]
            arr = np.asarray(inputs[s.name], dtype=s.dtype.np)
            assert arr.shape == p.shape, (s.name, arr.shape, p.shape)
            self.place_view(p, s.dtype.np)[...] = arr

    def read_outputs(self) -> dict[str, np.ndarray]:
        cdlt = self.program.cdlt
        out = {}
        for s in cdlt.surrogates.values():
            if s.kind == "out":
                p = self.program.memmap.places[s.name]
                out[s.name] = self.place_view(p, s.dtype.np).copy()
        return out

    # -- per-mnemonic semantics ----------------------------------------------
    def execute(self, m: Mnemonic) -> None:
        kind = m.sem[0]
        if kind == "loopi":
            return
        if kind == "alloc":
            _, p, fill, dtype = m.sem
            self.place_view(p, dtype)[...] = fill
            return
        if kind == "xfer":
            _, src_p, dst_p, vals, itemsize = m.sem
            rows, rb = vals["ROWS"], vals["ROW_BYTES"]
            ss, ds = vals["SRC_STRIDE"], vals["DST_STRIDE"]
            sbuf, dbuf = self.buffers[src_p.node], self.buffers[dst_p.node]
            sa, da = vals["SRC_ADDR"], vals["DST_ADDR"]
            for r in range(rows):
                dbuf[da + r * ds: da + r * ds + rb] = \
                    sbuf[sa + r * ss: sa + r * ss + rb]
            return
        if kind == "compute":
            _, capname, ins, outv, out_np = m.sem
            if capname in MATMUL_FAMILY:
                self._mac(capname, ins, outv, out_np)
            else:
                arrs = [np.asarray(self._view_of(v)) for v in ins]
                res = apply_elementwise(capname, out_np, arrs)
                dst = self._view_of(outv, out_np)
                dst[...] = res.reshape(dst.shape)
            return
        raise ValueError(f"unknown mnemonic semantics {kind!r}")

    def _dtype_of_place(self, place: Placement):
        for s in self.program.cdlt.surrogates.values():
            if self.program.memmap.places.get(s.name) is place:
                return s.dtype.np
        return np.int32

    def _view_of(self, v: dict, dtype=None) -> np.ndarray:
        dt = dtype if dtype is not None else self._dtype_of_place(v["place"])
        shape = v["shape"] or (1,)
        strides = tuple(s * v["place"].itemsize for s in v["strides"]) or \
            (v["place"].itemsize,)
        return self.view(v["place"].node, v["base"], shape, strides, dt)

    def _mac(self, capname, ins, outv, out_np) -> None:
        a = np.asarray(self._view_of(ins[0]))
        b = np.asarray(self._view_of(ins[1]))
        accv = ins[2] if len(ins) > 2 else outv
        acc = np.asarray(self._view_of(accv))
        la, lb = ins[0]["labels"], ins[1]["labels"]
        lc = outv["labels"]
        wide = np.int64 if np.issubdtype(np.dtype(out_np), np.integer) else np.float64
        prod = np.einsum(f"{la or ''},{lb or ''}->{lc or ''}",
                         a.astype(wide), b.astype(wide))
        res = (acc.astype(wide) + prod).astype(out_np)
        dst = self._view_of(outv, out_np)
        dst[...] = res.reshape(dst.shape)


# ---------------------------------------------------------------------------
# VLIW packet formation (§4)
# ---------------------------------------------------------------------------

SLOT_CAPACITY = {"mem": 2, "ctrl": 1}


def _slot_of(m: Mnemonic, acg: ACG) -> str:
    if m.sem[0] in ("xfer", "alloc"):
        return "mem"
    if m.sem[0] == "loopi":
        return "ctrl"
    node = acg.compute(m.node)
    return node.slot or "exec"


def _conflict(a: Mnemonic, b: Mnemonic) -> bool:
    """RAW / WAR / WAW between two mnemonics (byte-interval overlap)."""

    def overlap(xs, ys):
        for nx, lx, hx in xs:
            for ny, ly, hy in ys:
                if nx == ny and lx < hy and ly < hx:
                    return True
        return False

    return (overlap(a.wr, b.rd) or overlap(a.rd, b.wr) or overlap(a.wr, b.wr))


def pack_stream(program: Program, window: int = 12) -> list[list[int]]:
    """Greedy in-order packet formation with bounded hoisting.

    Follows §4: open a packet with the next unissued mnemonic, then hoist
    later mnemonics that (a) fit a free slot-class resource and the issue
    width, and (b) are independent of every unissued mnemonic they jump
    over *and* of every packet member.
    """
    acg = program.acg
    ms = program.mnemonics
    n = len(ms)
    issued = [False] * n
    packets: list[list[int]] = []
    i = 0
    while i < n:
        if issued[i]:
            i += 1
            continue
        packet = [i]
        issued[i] = True
        slots = {_slot_of(ms[i], acg): 1}
        if acg.issue_slots > 1:
            jumped: list[int] = []
            for j in range(i + 1, min(i + 1 + window, n)):
                if issued[j]:
                    continue
                if len(packet) >= acg.issue_slots:
                    break
                cand = ms[j]
                cls = _slot_of(cand, acg)
                if slots.get(cls, 0) >= SLOT_CAPACITY.get(cls, 1):
                    jumped.append(j)
                    continue
                if any(_conflict(ms[k], cand) or _conflict(cand, ms[k])
                       for k in packet) or any(
                        _conflict(ms[k], cand) for k in jumped):
                    jumped.append(j)
                    continue
                packet.append(j)
                issued[j] = True
                slots[cls] = slots.get(cls, 0) + 1
        packets.append(packet)
        i += 1
    return packets


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class StreamResult:
    outputs: dict[str, np.ndarray]
    serial_cycles: float
    packed_cycles: float
    n_mnemonics: int
    n_packets: int

    @property
    def packing_speedup(self) -> float:
        return self.serial_cycles / max(self.packed_cycles, 1e-9)


def run_stream(program: Program, inputs: dict[str, np.ndarray],
               pack: bool = True) -> StreamResult:
    machine = Machine(program)
    machine.load_inputs(inputs)
    serial = 0.0
    for m in program.mnemonics:
        machine.execute(m)
        serial += m.cycles
    if pack and program.acg.issue_slots > 1:
        packets = pack_stream(program)
        packed = float(sum(max(program.mnemonics[k].cycles for k in p) or 0
                           for p in packets))
        n_packets = len(packets)
    else:
        packed, n_packets = serial, len(program.mnemonics)
    return StreamResult(machine.read_outputs(), serial, packed,
                        len(program.mnemonics), n_packets)


__all__ = ["Machine", "StreamResult", "pack_stream", "run_stream"]
