"""Codelet optimization passes (§4): functions (Codelet, ACG) -> Codelet.

* ``granularize``  — align intra-loop strides / ref extents with the mapped
  capability's geometry (always applied; scalar baseline uses granularity-1
  capabilities so this is a no-op there).
* ``vectorize``    — re-map compute ops to the widest capability and split
  loops accordingly; for elementwise ops, the paper's Fig-9 heterogeneous
  split (SIMD main + scalar epilogue) avoids padding.
* ``unroll``       — replicate innermost compute bodies and coarsen transfer
  issue, amortizing loop/issue overhead (§4 Loop Unrolling).
* ``pack_body``    — the VLIW packing model (§4 Mnemonic Packing): given one
  loop body's mnemonic-level ops, return packed cycles assuming modulo
  scheduling bounded by per-slot-class resources.  Used by both the analytic
  cost model and the stream simulator's packet former.
"""
from __future__ import annotations

import copy
import math

from .acg import ACG
from .codelet import Aff, Codelet, Compute, Loop, Ref, Transfer
from .scheduler import capability_candidates

# ---------------------------------------------------------------------------
# granularity alignment
# ---------------------------------------------------------------------------

ROLE_ORDER = ("m", "n", "k")


def _choose_role_vars(cdlt: Codelet, op: Compute) -> dict[str, str]:
    """Pick, per role, the loop var that the capability geometry maps onto:
    the var with the largest *extent* (ties -> innermost).  Extent, not trip
    count, so the choice is stable when granularize re-runs after strides
    were already set (idempotence)."""
    intra = {l.var: (l.stop - l.start) for l in cdlt.loops() if l.role == "intra"}
    chosen = {}
    for role, vars_ in op.roles.items():
        avail = [v0 for v0 in vars_ if v0 in intra]
        if not avail:
            continue
        chosen[role] = max(avail, key=lambda v0: (intra[v0], vars_.index(v0)))
    return chosen


def _role_granularity(op: Compute) -> dict[str, int]:
    c = op.cap_obj
    if c.geometry is not None:
        return dict(zip(ROLE_ORDER, c.geometry))
    return {"n": c.out_elems}


def granularize(cdlt: Codelet, acg: ACG) -> None:
    """Set intra-loop strides + compute-ref extents to match capability
    geometry.  Partial trailing invocations are clamped (ceil semantics).
    Idempotent: strides owned by this pass are reset before re-choosing."""
    role_vars = {v0 for _, op in cdlt.computes() for vars_ in op.roles.values()
                 for v0 in vars_}
    for l in cdlt.loops():
        if l.role == "intra" and l.var in role_vars:
            l.stride = 1
    for _, op in cdlt.computes():
        if op.cap_obj is None:
            continue
        gran = _role_granularity(op)
        chosen = _choose_role_vars(cdlt, op)
        vec: dict[str, int] = {}  # loop var -> granularity
        for role, g in gran.items():
            if g > 1 and role in chosen:
                vec[chosen[role]] = g
        for l in cdlt.loops():
            if l.role == "intra" and l.var in vec and any(
                    o is op for o in _ops_under(l)):
                l.stride = vec[l.var]
        op.vec = vec  # type: ignore[attr-defined]  # consumed by cost/interp
        _set_ref_extents(op, vec)


def _ops_under(loop: Loop):
    for item in loop.body:
        if isinstance(item, Loop):
            yield from _ops_under(item)
        else:
            yield item


def _set_ref_extents(op: Compute, vec: dict[str, int]) -> None:
    """Per-dim extent each invocation touches: sum(coeff*(g(var)-1)) + 1."""

    def extents(r: Ref) -> Ref:
        sizes = []
        for ix in r.idx:
            e = 1
            for var, coeff in ix.terms:
                if var in vec:
                    e += abs(coeff) * (vec[var] - 1)
            sizes.append(e)
        return Ref(r.var, r.idx, tuple(sizes) if sizes else None)

    op.out = extents(op.out)
    op.ins = tuple(extents(i) for i in op.ins)


# ---------------------------------------------------------------------------
# vectorization (§4 Parallelization, Fig 9)
# ---------------------------------------------------------------------------


def vectorize(cdlt: Codelet, acg: ACG) -> None:
    """Re-map every compute op to the widest supporting capability, then
    re-granularize.  Elementwise ops with a lane remainder get the Fig-9
    heterogeneous split: vector main loop + scalar epilogue on a second
    compute node, covering the tensor without padding."""
    for loops, op in list(cdlt.computes()):
        cands = capability_candidates(acg, op)
        node, c = cands[0]
        op.loc, op.cap_obj = node.name, c
    granularize(cdlt, acg)
    _hetero_epilogue(cdlt, acg)
    cdlt.note("vectorize: re-mapped to widest capabilities")


def _hetero_epilogue(cdlt: Codelet, acg: ACG) -> None:
    for loops, op in list(cdlt.computes()):
        if op.cap_obj is None or op.cap_obj.geometry is not None:
            continue  # matmul family uses clamped invocations instead
        lanes = op.cap_obj.out_elems
        if lanes <= 1 or not loops:
            continue
        inner = loops[-1]
        if inner.stride != lanes:
            continue
        rem = (inner.stop - inner.start) % lanes
        if rem == 0:
            continue
        # scalar fallback node (Fig 9's "PE")
        scalars = [nc for nc in capability_candidates(acg, op)
                   if nc[1].out_elems < lanes]
        if not scalars:
            continue  # no narrower unit: keep clamped final invocation
        snode, scap = scalars[-1]
        cov = inner.stop - rem
        inner.stop = cov
        epi_op = copy.deepcopy(op)
        epi_op.loc, epi_op.cap_obj = snode.name, scap
        epi_op.vec = {}  # type: ignore[attr-defined]
        _set_ref_extents(epi_op, {})
        epi = Loop(inner.var, cov, cov + rem, scap.out_elems, [epi_op], role="intra")
        parent_body = _parent_body(cdlt, inner)
        parent_body.insert(parent_body.index(inner) + 1, epi)
        cdlt.note(
            f"vectorize: Fig-9 split on {inner.var}: [{inner.start},{cov}) on "
            f"{op.loc} x{lanes}, [{cov},{cov+rem}) on {snode.name}")


def _parent_body(cdlt: Codelet, target: Loop) -> list:
    def rec(body):
        if any(item is target for item in body):
            return body
        for item in body:
            if isinstance(item, Loop):
                found = rec(item.body)
                if found is not None:
                    return found
        return None

    found = rec(cdlt.body)
    assert found is not None
    return found


# ---------------------------------------------------------------------------
# loop unrolling (§4)
# ---------------------------------------------------------------------------


def unroll(cdlt: Codelet, acg: ACG, factor: int = 4) -> None:
    """§4 Loop Unrolling.

    Two effects, both modeled mnemonic-faithfully:

    * innermost compute loops are replicated ``u`` times (fewer loop-overhead
      ctrl ops, more independent mnemonics for the packer);
    * every staging transfer gets ``coalesce=u``: a single XFER mnemonic may
      now carry up to ``u`` contiguous rows (bounded by edge bandwidth) —
      the paper's "if the transfer size is less than the edge bandwidth,
      more data can be transferred in a single operation".
    """
    for l in _innermost_compute_loops(cdlt):
        u = _largest_divisor_leq(l.trips, factor)
        if u <= 1:
            continue
        new_body = []
        for j in range(u):
            for item in l.body:
                clone = copy.deepcopy(item)
                if j > 0:
                    _shift_refs(clone, l.var, j * l.stride)
                new_body.append(clone)
        l.body = new_body
        l.stride *= u
        l.role = "unrolled"
        cdlt.note(f"unroll: {l.var} x{u}")
    for _, t in cdlt.transfers():
        t.coalesce = factor  # type: ignore[attr-defined]


def _innermost_compute_loops(cdlt: Codelet) -> list[Loop]:
    out = []
    for l in cdlt.loops():
        if any(isinstance(x, Compute) for x in l.body) and not any(
                isinstance(x, Loop) for x in l.body):
            out.append(l)
    return out


def _largest_divisor_leq(n: int, k: int) -> int:
    for d in range(min(n, k), 0, -1):
        if n % d == 0:
            return d
    return 1


def _shift_refs(item, var: str, delta: int) -> None:
    if isinstance(item, Compute):
        item.out = _shift_ref(item.out, var, delta)
        item.ins = tuple(_shift_ref(r, var, delta) for r in item.ins)
    elif isinstance(item, Transfer):
        item.src = _shift_ref(item.src, var, delta)
        if item.dst is not None:
            item.dst = _shift_ref(item.dst, var, delta)


def _shift_ref(r: Ref, var: str, delta: int) -> Ref:
    new_idx = []
    for ix in r.idx:
        coeff = dict(ix.terms).get(var, 0)
        new_idx.append(Aff(ix.terms, ix.const + coeff * delta))
    return Ref(r.var, tuple(new_idx), r.sizes)


# ---------------------------------------------------------------------------
# mnemonic packing model (§4)
# ---------------------------------------------------------------------------

# per-packet capacity of each slot class (HVX-style VLIW: 1 vector op, 1
# scalar op, 1 load/store pair, control folded into scalar)
DEFAULT_SLOT_CAPACITY = {"mem": 2, "ctrl": 1}


def pack_body(ops: list[tuple[str, float]], acg: ACG) -> float:
    """Packed cycles for one loop-body iteration.

    ``ops`` is [(slot_class, cycles)].  Models software-pipelined modulo
    scheduling: the initiation interval is bounded below by per-class
    resource usage and by total issue width; we return that bound (the
    packing algorithm in codegen realises it on real streams).
    """
    if acg.issue_slots <= 1:
        return sum(c for _, c in ops)
    per_class: dict[str, float] = {}
    for cls, cyc in ops:
        per_class[cls] = per_class.get(cls, 0.0) + cyc
    ii = 0.0
    for cls, cyc in per_class.items():
        capn = DEFAULT_SLOT_CAPACITY.get(cls, 1)
        ii = max(ii, cyc / capn)
    ii = max(ii, sum(c for _, c in ops) / acg.issue_slots, 1.0)
    return ii


__all__ = ["granularize", "pack_body", "unroll", "vectorize"]
