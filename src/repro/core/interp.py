"""Functional interpreter for scheduled Codelets.

Executes the transformed Codelet (tile loops + transfers + mapped compute
ops) against numpy storage, one compute *invocation* at a time — the same
granularity the generated mnemonics have.  This is the correctness half of
the simulator; the cycle half is ``cost.py`` (analytic) and
``stream.py`` (per-mnemonic, for small streams).

Partial trailing invocations (ceil-tripped vector loops) are clamped to the
loop bound, matching the clamp semantics the code generator emits.
"""
from __future__ import annotations

import math

import numpy as np

from .acg import ACG
from .codelet import Aff, Codelet, Compute, Loop, Ref, Transfer
from .semantics import MATMUL_FAMILY, apply_elementwise, apply_mac


class InterpError(RuntimeError):
    pass


def run(cdlt: Codelet, acg: ACG, inputs: dict[str, np.ndarray]) -> dict[str, np.ndarray]:
    """Execute the scheduled codelet; returns {out_name: array}."""
    store: dict[str, np.ndarray] = {}
    for s in cdlt.surrogates.values():
        if s.kind == "inp":
            a = np.asarray(inputs[s.name], dtype=s.dtype.np)
            if a.shape != s.shape:
                raise InterpError(f"{s.name}: expected {s.shape}, got {a.shape}")
            store[s.name] = a
        elif s.kind == "out":
            store[s.name] = np.zeros(s.shape, dtype=s.dtype.np)

    # loop bound map for clamping (rebuilt as we enter loops)
    bounds: dict[str, tuple[int, int]] = {}  # var -> (stride, stop)

    def eval_aff(ix: Aff, env) -> int:
        return ix.const + sum(c * env.get(var, 0) for var, c in ix.terms)

    def eff(var: str, gran: int, env) -> int:
        """Invocation extent along ``var``: the capability granularity,
        clamped by the loop bound (partial trailing invocation)."""
        _, stop = bounds.get(var, (1, 1 << 62))
        return max(1, min(gran, stop - env.get(var, 0)))

    def slice_spec(r: Ref, vec: dict[str, int], env):
        """Per-dim (start, count, step) honoring vectorized vars."""
        spec = []
        for ix in r.idx:
            vec_terms = [(var, c) for var, c in ix.terms if var in vec]
            start = eval_aff(ix, env)
            if not vec_terms:
                spec.append((start, 1, 1))
            elif len(vec_terms) == 1:
                var, c = vec_terms[0]
                spec.append((start, eff(var, vec[var], env), abs(c) or 1))
            else:
                raise InterpError(f"dim mixes vectorized vars: {ix}")
        return spec

    def read(r: Ref, vec, env) -> np.ndarray:
        a = store[r.var]
        if not r.idx:
            return a
        sl = tuple(slice(st, st + cnt * stp, stp)
                   for st, cnt, stp in slice_spec(r, vec, env))
        return a[sl]

    def write(r: Ref, vec, env, val: np.ndarray) -> None:
        a = store[r.var]
        if not r.idx:
            a[...] = val
            return
        sl = tuple(slice(st, st + cnt * stp, stp)
                   for st, cnt, stp in slice_spec(r, vec, env))
        a[sl] = val.reshape(a[sl].shape)

    def read_labeled(r: Ref, vec, role_of, env) -> tuple[np.ndarray, str]:
        """Slice + reshape to exactly the labeled (vectorized) dims."""
        a = store[r.var]
        if not r.idx:
            return a, ""
        spec = slice_spec(r, vec, env)
        sl = tuple(slice(st, st + cnt * stp, stp) for st, cnt, stp in spec)
        arr = a[sl]
        labels, shape = [], []
        for d, ix in enumerate(r.idx):
            vt = [var for var, _ in ix.terms if var in vec]
            if vt:
                labels.append(role_of[vt[0]])
                shape.append(arr.shape[d])
        return arr.reshape(tuple(shape)), "".join(labels)

    def exec_compute(op: Compute, env) -> None:
        vec = getattr(op, "vec", {}) or {}
        if op.capability in MATMUL_FAMILY:
            role_of = {}
            for role, vars_ in op.roles.items():
                for var in vars_:
                    if var in vec:
                        role_of[var] = role
            a, la = read_labeled(op.ins[0], vec, role_of, env)
            b, lb = read_labeled(op.ins[1], vec, role_of, env)
            acc, _ = read_labeled(op.ins[2] if len(op.ins) > 2 else op.out,
                                  vec, role_of, env)
            lc = read_labeled(op.out, vec, role_of, env)[1]
            res = apply_mac(op.dtype.np, a, b, acc, (la, lb, lc))
            write(op.out, vec, env, res)
        else:
            ins = [read(i, vec, env) for i in op.ins]
            res = apply_elementwise(op.capability, op.dtype.np, ins)
            write(op.out, vec, env, res)

    def exec_transfer(t: Transfer, env) -> None:
        if t.dst_loc is not None:
            s = cdlt.surrogates[t.alloc]
            if not t.src.var:  # const-fill allocation
                store[t.alloc] = np.full(s.shape, t.fill, dtype=s.dtype.np)
                return
            src = cdlt.surrogates[t.src.var]
            start = [eval_aff(ix, env) for ix in t.src.idx] or [0] * len(t.sizes)
            tile = np.zeros(t.sizes, dtype=s.dtype.np)
            src_arr = store[t.src.var]
            spans = [min(sz, src_arr.shape[d] - st)
                     for d, (st, sz) in enumerate(zip(start, t.sizes))]
            region = tuple(slice(st, st + sp) for st, sp in zip(start, spans))
            tile[tuple(slice(0, sp) for sp in spans)] = src_arr[region]
            store[t.alloc] = tile
        else:
            src_arr = store[t.src.var]
            dst = cdlt.surrogates[t.dst.var]
            start = [eval_aff(ix, env) for ix in t.dst.idx] or [0] * len(t.sizes)
            dst_arr = store[t.dst.var]
            spans = [min(sz, dst_arr.shape[d] - st)
                     for d, (st, sz) in enumerate(zip(start, t.sizes))]
            region = tuple(slice(st, st + sp) for st, sp in zip(start, spans))
            dst_arr[region] = src_arr[tuple(slice(0, sp) for sp in spans)]

    def exec_body(body: list, env: dict[str, int]) -> None:
        for item in body:
            if isinstance(item, Loop):
                bounds[item.var] = (item.stride, item.stop)
                x = item.start
                while x < item.stop:
                    env[item.var] = x
                    exec_body(item.body, env)
                    x += item.stride
                env.pop(item.var, None)
            elif isinstance(item, Transfer):
                exec_transfer(item, env)
            elif isinstance(item, Compute):
                exec_compute(item, env)

    exec_body(cdlt.body, {})
    return {s.name: store[s.name] for s in cdlt.surrogates.values() if s.kind == "out"}


__all__ = ["InterpError", "run"]
