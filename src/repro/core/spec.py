"""Declarative Covenant specs — the ACG as *data*.

The paper's adaptability claim (§2: design changes absorbed "without
complete compiler redevelopment") only holds if an accelerator can be
described without writing compiler-adjacent code.  An ``ACGSpec`` is that
description: a frozen, serializable value covering everything an ACG
carries — memories, compute capabilities, edges, mnemonic layouts, cost
attributes — with

* ``ACG.from_spec(spec)`` / ``acg.to_spec()`` round-tripping losslessly
  (byte-identical instruction streams, tested per paper layer);
* ``spec.fingerprint()`` — a canonical content hash that is the ACG
  component of every compile-cache and ``ArtifactStore`` key, so two
  distinct in-memory ACGs can never alias on a name and a mutated ACG can
  never collect a stale warm hit;
* ``spec.derive(**overrides)`` — architecture families as data: scale the
  PE array (``pe="32x32"``), resize a scratchpad (``memories={"VMEM1":
  {"depth": 4096}}``), re-rate an interconnect, and recompile every paper
  layer against the variant.  Derived specs get a canonical
  ``base@key=value`` name that the target registry resolves directly
  (``repro.compile(layer, "dnnweaver@pe=32x32")``).

``validate_spec`` performs the structural half of covenant validation
(``core/covenant.py`` holds the codelet-vs-ACG half): every problem is a
named, actionable message instead of a ``KeyError`` three passes deep.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
from typing import Iterable, Mapping, Sequence

from .dtypes import dt

# ---------------------------------------------------------------------------
# spec data model — frozen, hashable, JSON-serializable
# ---------------------------------------------------------------------------

# One capability operand as data: (dtype name, *shape), e.g. ("i8", 64, 64).
Operand = tuple


@dataclasses.dataclass(frozen=True)
class MemorySpec:
    name: str
    data_width: int   # bits per bank access
    banks: int
    depth: int
    offchip: bool = False


@dataclasses.dataclass(frozen=True)
class CapabilitySpec:
    name: str
    outputs: tuple[Operand, ...]
    inputs: tuple[Operand, ...]
    cycles: int = 1
    geometry: tuple[int, int, int] | None = None


@dataclasses.dataclass(frozen=True)
class ComputeSpec:
    name: str
    capabilities: tuple[CapabilitySpec, ...]
    slot: str | None = None


@dataclasses.dataclass(frozen=True)
class EdgeSpec:
    src: str
    dst: str
    bandwidth: int    # bits per transfer operation
    latency: int = 1


@dataclasses.dataclass(frozen=True)
class FieldSpec:
    name: str
    bits: int
    enum: tuple[str, ...] | None = None   # efield when set, ifield otherwise
    rw: str | None = None                 # "r" | "w" | None


@dataclasses.dataclass(frozen=True)
class MnemonicSpec:
    name: str
    opcode: int
    fields: tuple[FieldSpec, ...]
    attrs: tuple[tuple[str, object], ...] = ()


@dataclasses.dataclass(frozen=True)
class ACGSpec:
    """A complete, declarative covenant: everything ``ACG.from_spec`` needs.

    Node order is significant — mnemonic enum fields index memories and
    compute units by declaration order — so ``memories`` / ``computes`` /
    ``edges`` / ``mnemonics`` are ordered tuples, not sets.
    """

    name: str
    memories: tuple[MemorySpec, ...]
    computes: tuple[ComputeSpec, ...]
    edges: tuple[EdgeSpec, ...]
    mnemonics: tuple[MnemonicSpec, ...]
    issue_slots: int = 1
    loop_overhead: int = 1
    # ((compute node, capability name), (staging memory per operand, output last))
    operand_ports: tuple[tuple[tuple[str, str], tuple[str, ...]], ...] = ()

    # -- serialization -------------------------------------------------------
    def to_dict(self) -> dict:
        # hand-rolled (dataclasses.asdict recursion is ~8x slower, and this
        # runs on every compile to fingerprint the target)
        return {
            "name": self.name,
            "issue_slots": self.issue_slots,
            "loop_overhead": self.loop_overhead,
            "memories": [
                {"name": m.name, "data_width": m.data_width,
                 "banks": m.banks, "depth": m.depth, "offchip": m.offchip}
                for m in self.memories],
            "computes": [
                {"name": c.name, "slot": c.slot, "capabilities": [
                    {"name": k.name,
                     "outputs": [list(o) for o in k.outputs],
                     "inputs": [list(i) for i in k.inputs],
                     "cycles": k.cycles,
                     "geometry": (list(k.geometry)
                                  if k.geometry is not None else None)}
                    for k in c.capabilities]}
                for c in self.computes],
            "edges": [
                {"src": e.src, "dst": e.dst, "bandwidth": e.bandwidth,
                 "latency": e.latency} for e in self.edges],
            # attrs and operand_ports are canonically ordered HERE, not only
            # in spec_of(): the fingerprint must be identical no matter how
            # the spec was constructed (builder, from_json, direct), or the
            # round-trip identity and the driver's spec memo break
            "mnemonics": [
                {"name": m.name, "opcode": m.opcode, "fields": [
                    {"name": f.name, "bits": f.bits,
                     "enum": (list(f.enum) if f.enum is not None else None),
                     "rw": f.rw} for f in m.fields],
                 "attrs": sorted((list(kv) for kv in m.attrs),
                                 key=lambda kv: kv[0])}
                for m in self.mnemonics],
            "operand_ports": sorted(
                ([list(k), list(v)] for k, v in self.operand_ports),
                key=lambda e: e[0]),
        }

    @classmethod
    def from_dict(cls, d: Mapping) -> "ACGSpec":
        return cls(
            name=d["name"],
            memories=tuple(MemorySpec(**m) for m in d["memories"]),
            computes=tuple(
                ComputeSpec(
                    name=c["name"],
                    capabilities=tuple(
                        CapabilitySpec(
                            name=k["name"],
                            outputs=tuple(tuple(o) for o in k["outputs"]),
                            inputs=tuple(tuple(i) for i in k["inputs"]),
                            cycles=k.get("cycles", 1),
                            geometry=(tuple(k["geometry"])
                                      if k.get("geometry") else None),
                        ) for k in c["capabilities"]),
                    slot=c.get("slot"),
                ) for c in d["computes"]),
            edges=tuple(EdgeSpec(**e) for e in d["edges"]),
            mnemonics=tuple(
                MnemonicSpec(
                    name=m["name"], opcode=m["opcode"],
                    fields=tuple(
                        FieldSpec(name=f["name"], bits=f["bits"],
                                  enum=(tuple(f["enum"]) if f.get("enum")
                                        else None),
                                  rw=f.get("rw"))
                        for f in m["fields"]),
                    attrs=tuple((k, v) for k, v in m.get("attrs", ())),
                ) for m in d["mnemonics"]),
            issue_slots=d.get("issue_slots", 1),
            loop_overhead=d.get("loop_overhead", 1),
            operand_ports=tuple(
                ((n, c), tuple(ports))
                for (n, c), ports in d.get("operand_ports", ())),
        )

    def to_json(self, indent: int | None = None) -> str:
        return json.dumps(self.to_dict(), indent=indent, sort_keys=True)

    @classmethod
    def from_json(cls, text: str) -> "ACGSpec":
        return cls.from_dict(json.loads(text))

    def fingerprint(self) -> str:
        """Canonical content hash — the ACG component of compile-cache and
        artifact-store keys.  Covers *everything* in the spec, including
        mnemonic field layouts (which the old describe()-based hash missed),
        so structurally different targets can never alias.

        Mnemonic ``attrs`` holding non-JSON values hash via ``repr``:
        reprs that embed object addresses make the fingerprint
        process-local — distinct values never alias (the safe direction,
        same policy as the pipeline's closure-capture tags), at the cost
        of cross-process warm store hits for such exotic targets."""
        payload = json.dumps(self.to_dict(), sort_keys=True,
                             separators=(",", ":"), default=repr)
        return hashlib.sha256(payload.encode()).hexdigest()

    # -- derivation ----------------------------------------------------------
    def derive(self, name: str | None = None, *, pe: str | tuple | None = None,
               issue_slots: int | None = None, loop_overhead: int | None = None,
               memories: Mapping[str, Mapping] | None = None,
               edges: Mapping[tuple[str, str], Mapping] | None = None,
               ) -> "ACGSpec":
        """A perturbed copy of this spec — one member of an architecture
        family (the paper's adaptability claim as a runnable sweep).

        * ``pe="32x32"`` (or ``(32, 32)``) rescales the PE array: on the
          PE-grid unit (the one owning the largest matmul geometry by
          invocation size), dimensions equal to the base array dimension
          are replaced in operand shapes and geometry alike; every other
          unit — including SIMD/vector lanes that happen to match the
          array width — is untouched, so the sweep varies one design
          axis.  Square arrays only.
        * ``memories={"VMEM1": {"depth": 4096}}`` resizes storage nodes.
        * ``edges={("DRAM", "IBUF"): {"bandwidth": 512}}`` re-rates
          interconnect.
        * ``issue_slots`` / ``loop_overhead`` override the scalar knobs.

        Unless ``name`` is given, the derived spec is named canonically —
        ``base@key=value,...`` with sorted tokens — which the target
        registry parses back, so the name alone reproduces the variant.
        """
        new_mem = self.memories
        new_cu = self.computes
        new_edges = self.edges
        tokens: dict[str, str] = _name_tokens(self.name)
        base = self.name.partition("@")[0]

        if pe is not None:
            rows, cols = _parse_pe(pe)
            grid = _pe_grid(self.computes)
            if grid is None:
                raise SpecError(self.name, [
                    "pe override: no capability with matmul-family geometry "
                    "to rescale"])
            unit, old = grid
            if rows != cols:
                raise SpecError(self.name, [
                    f"pe override {rows}x{cols}: only square PE arrays are "
                    f"derivable (base array is {old}x{old})"])
            new_cu = tuple(_scale_compute(c, old, rows) if c.name == unit
                           else c for c in new_cu)
            tokens["pe"] = f"{rows}x{cols}"
        if memories:
            by_name = {m.name: m for m in new_mem}
            for mname, fields in memories.items():
                if mname not in by_name:
                    raise SpecError(self.name, [
                        f"memory override: no memory node {mname!r} "
                        f"(have: {sorted(by_name)})"])
                bad = set(fields) - {"data_width", "banks", "depth", "offchip"}
                if bad:
                    raise SpecError(self.name, [
                        f"memory override {mname}: unknown field(s) "
                        f"{sorted(bad)}"])
                by_name[mname] = dataclasses.replace(by_name[mname], **fields)
                for f, val in sorted(fields.items()):
                    tokens[f"{mname}.{f}"] = str(val)
            new_mem = tuple(by_name[m.name] for m in new_mem)
        if edges:
            known = {(e.src, e.dst) for e in new_edges}
            for key, fields in edges.items():
                if tuple(key) not in known:
                    raise SpecError(self.name, [
                        f"edge override: no edge {key[0]}->{key[1]}"])
                bad = set(fields) - {"bandwidth", "latency"}
                if bad:
                    raise SpecError(self.name, [
                        f"edge override {key[0]}->{key[1]}: unknown "
                        f"field(s) {sorted(bad)}"])
                for f, val in sorted(fields.items()):
                    tokens[f"edge.{key[0]}.{key[1]}.{f}"] = str(val)
            new_edges = tuple(
                dataclasses.replace(e, **dict(edges.get((e.src, e.dst), {})))
                for e in new_edges)
        if issue_slots is not None:
            tokens["issue_slots"] = str(issue_slots)
        if loop_overhead is not None:
            tokens["loop_overhead"] = str(loop_overhead)

        if name is None:
            suffix = ",".join(f"{k}={v}" for k, v in sorted(tokens.items()))
            name = f"{base}@{suffix}" if suffix else base
        out = dataclasses.replace(
            self, name=name, memories=new_mem, computes=new_cu,
            edges=new_edges,
            issue_slots=(issue_slots if issue_slots is not None
                         else self.issue_slots),
            loop_overhead=(loop_overhead if loop_overhead is not None
                           else self.loop_overhead))
        validate_spec(out)
        return out

    def __repr__(self) -> str:
        return (f"ACGSpec({self.name!r}, {len(self.memories)} mem, "
                f"{len(self.computes)} cu, {len(self.edges)} edges, "
                f"{len(self.mnemonics)} mnemonics)")


def _name_tokens(name: str) -> dict[str, str]:
    """The ``k=v`` override tokens already present in a derived name, so
    deriving a derived spec merges instead of nesting ``@`` suffixes."""
    _, sep, suffix = name.partition("@")
    if not sep:
        return {}
    out = {}
    for tok in suffix.split(","):
        k, _, v = tok.partition("=")
        if k and v:
            out[k] = v
    return out


def _parse_pe(pe) -> tuple[int, int]:
    if isinstance(pe, str):
        parts = pe.lower().split("x")
        try:
            if len(parts) != 2:
                raise ValueError
            return int(parts[0]), int(parts[1])
        except ValueError:
            raise SpecError("pe", [f"pe override must look like '32x32', "
                                   f"got {pe!r}"]) from None
    rows, cols = pe
    return int(rows), int(cols)


def _pe_grid(computes: Sequence[ComputeSpec]) -> tuple[str, int] | None:
    """(unit name, base PE-array dimension) of the PE grid: the compute
    unit owning the capability with the largest geometry *product* (MACs
    per invocation — the array size), whose max dim is the array
    dimension.  Distinguishes the systolic array from e.g. a SIMD unit
    whose lane count happens to equal the array width."""
    best: tuple[str, int] | None = None
    best_size = 1
    for c in computes:
        for k in c.capabilities:
            if k.geometry is not None:
                size = k.geometry[0] * k.geometry[1] * k.geometry[2]
                if size > best_size and max(k.geometry) > 1:
                    best = (c.name, max(k.geometry))
                    best_size = size
    return best


def _scale_compute(c: ComputeSpec, old: int, new: int) -> ComputeSpec:
    """Rescale the PE-grid unit: only capabilities whose *geometry* carries
    the base array dimension are touched — and ``derive`` only calls this
    for the unit ``_pe_grid`` identified, so sibling vector/SIMD units
    (even ones whose lane count equals the array width) keep their shapes
    and a ``pe=`` sweep varies exactly one design axis."""
    def dim(d: int) -> int:
        return new if d == old else d

    def operand(o: Operand) -> Operand:
        return (o[0],) + tuple(dim(d) for d in o[1:])

    def scale(k: CapabilitySpec) -> CapabilitySpec:
        if k.geometry is None or old not in k.geometry:
            return k
        return dataclasses.replace(
            k,
            outputs=tuple(operand(o) for o in k.outputs),
            inputs=tuple(operand(i) for i in k.inputs),
            geometry=tuple(dim(d) for d in k.geometry))

    return dataclasses.replace(
        c, capabilities=tuple(scale(k) for k in c.capabilities))


def parse_overrides(text: str) -> dict:
    """Parse a variant suffix (``"pe=32x32,VMEM1.depth=4096"``) into
    ``derive()`` keyword arguments.  Grammar, one ``key=value`` per comma:

    * ``pe=RxC``                      — PE-array rescale
    * ``issue_slots=N`` / ``loop_overhead=N``
    * ``<MEM>.<field>=N``             — memory node override
    * ``edge.<SRC>.<DST>.<field>=N``  — edge override
    """
    def as_int(key: str, val: str) -> int:
        try:
            return int(val)
        except ValueError:
            raise SpecError(text, [
                f"override {key}={val!r}: value must be an integer"]) \
                from None

    kwargs: dict = {}
    for tok in filter(None, (t.strip() for t in text.split(","))):
        key, sep, val = tok.partition("=")
        if not sep or not val:
            raise SpecError(text, [f"override token {tok!r} is not "
                                   f"'key=value'"])
        if key == "pe":
            kwargs["pe"] = val
        elif key in ("issue_slots", "loop_overhead"):
            kwargs[key] = as_int(key, val)
        elif key.startswith("edge."):
            parts = key.split(".")
            if len(parts) != 4:
                raise SpecError(text, [
                    f"edge override {key!r} must be "
                    f"'edge.<SRC>.<DST>.<field>'"])
            _, src, dst, field = parts
            kwargs.setdefault("edges", {}).setdefault((src, dst), {})[
                field] = as_int(key, val)
        elif "." in key:
            mname, _, field = key.partition(".")
            if field == "offchip":
                low = val.lower()
                if low not in ("true", "false", "1", "0"):
                    raise SpecError(text, [
                        f"override {key}={val!r}: value must be a boolean "
                        f"(true/false/1/0)"])
                value: object = low in ("true", "1")
            else:
                value = as_int(key, val)
            kwargs.setdefault("memories", {}).setdefault(mname, {})[
                field] = value
        else:
            raise SpecError(text, [
                f"unknown override key {key!r}; expected pe, issue_slots, "
                f"loop_overhead, <MEM>.<field> or edge.<SRC>.<DST>.<field>"])
    return kwargs


# ---------------------------------------------------------------------------
# terse spec builders (mirror acg.cap / acg.ospec)
# ---------------------------------------------------------------------------


def smem(name: str, data_width: int, banks: int, depth: int,
         offchip: bool = False) -> MemorySpec:
    return MemorySpec(name, data_width, banks, depth, offchip)


def sop(dtype: str, *shape: int) -> Operand:
    """One capability operand: ``sop("i8", 64, 64)``."""
    return (dtype,) + (shape if shape else (1,))


def scap(name: str, outputs, inputs, cycles: int = 1,
         geometry: tuple[int, int, int] | None = None) -> CapabilitySpec:
    # a bare operand tuple is promoted to a one-operand list on both sides
    if outputs and isinstance(outputs[0], str):
        outputs = (outputs,)
    if inputs and isinstance(inputs[0], str):
        inputs = (inputs,)
    return CapabilitySpec(name, tuple(tuple(o) for o in outputs),
                          tuple(tuple(i) for i in inputs), cycles,
                          tuple(geometry) if geometry else None)


def scu(name: str, capabilities: Iterable[CapabilitySpec],
        slot: str | None = None) -> ComputeSpec:
    return ComputeSpec(name, tuple(capabilities), slot)


def sedge(src: str, dst: str, bandwidth: int, latency: int = 1,
          bidir: bool = False) -> list[EdgeSpec]:
    out = [EdgeSpec(src, dst, bandwidth, latency)]
    if bidir:
        out.append(EdgeSpec(dst, src, bandwidth, latency))
    return out


# Elementwise capability names shared across targets (Table 1).
UNARY = ("RELU", "SIGMOID", "TANH")
BINARY = ("ADD", "SUB", "MUL", "DIV", "MAX", "MIN")


def common_mnemonics(mem_names: Sequence[str], unit_names: Sequence[str],
                     addr_bits: int = 24) -> tuple[MnemonicSpec, ...]:
    """The target-independent mnemonic vocabulary (§2.1.4): XFER / ALLOC /
    LOOPI plus one mnemonic per Table-1 capability family.  Per-target
    variation is only field widths and node enums — the paper's
    'semantics-free' reuse claim as a spec generator."""
    mems = tuple(mem_names)
    units = tuple(unit_names)
    out = [
        MnemonicSpec("XFER", 0x01, (
            FieldSpec("SRC_NODE", 4, mems, "r"),
            FieldSpec("DST_NODE", 4, mems, "w"),
            FieldSpec("SRC_ADDR", addr_bits, None, "r"),
            FieldSpec("DST_ADDR", addr_bits, None, "w"),
            FieldSpec("ROWS", 16),
            FieldSpec("ROW_BYTES", 24),
            FieldSpec("SRC_STRIDE", 24),
            FieldSpec("DST_STRIDE", 24),
        )),
        MnemonicSpec("ALLOC", 0x02, (
            FieldSpec("NODE", 4, mems, "w"),
            FieldSpec("ADDR", addr_bits, None, "w"),
            FieldSpec("SIZE", 24),
        )),
        MnemonicSpec("LOOPI", 0x03, (
            FieldSpec("LEVEL", 8), FieldSpec("TRIP", 24),
        )),
    ]
    for i, name in enumerate(UNARY):
        out.append(MnemonicSpec(name, 0x10 + i, (
            FieldSpec("SRC_ADDR", addr_bits, None, "r"),
            FieldSpec("DST_ADDR", addr_bits, None, "w"),
            FieldSpec("N", 16),
            FieldSpec("TGT", 3, units),
        )))
    for i, name in enumerate(BINARY):
        out.append(MnemonicSpec(name, 0x20 + i, (
            FieldSpec("SRC1_ADDR", addr_bits, None, "r"),
            FieldSpec("SRC2_ADDR", addr_bits, None, "r"),
            FieldSpec("DST_ADDR", addr_bits, None, "w"),
            FieldSpec("N", 16),
            FieldSpec("TGT", 3, units),
        )))
    for i, name in enumerate(("MAC", "GEMM", "MMUL", "MVMUL")):
        out.append(MnemonicSpec(name, 0x30 + i, (
            FieldSpec("SRC1_ADDR", addr_bits, None, "r"),
            FieldSpec("SRC2_ADDR", addr_bits, None, "r"),
            FieldSpec("ACC_ADDR", addr_bits, None, "r"),
            FieldSpec("DST_ADDR", addr_bits, None, "w"),
            FieldSpec("M", 16), FieldSpec("N", 16), FieldSpec("K", 16),
            FieldSpec("LD1", 16), FieldSpec("LD2", 16), FieldSpec("LDD", 16),
            FieldSpec("TGT", 3, units),
        )))
    return tuple(out)


def acg_spec(name: str, memories, computes, edges, *,
             mnemonics: Sequence[MnemonicSpec] | None = None,
             addr_bits: int = 24, issue_slots: int = 1,
             loop_overhead: int = 1, operand_ports=()) -> ACGSpec:
    """Assemble a normalized ``ACGSpec``.  ``edges`` may nest (the
    ``sedge(..., bidir=True)`` idiom); ``mnemonics=None`` derives the
    common vocabulary at ``addr_bits`` — always materialized explicitly so
    the canonical form (and fingerprint) never depends on shorthand."""
    memories = tuple(memories)
    computes = tuple(computes)
    flat_edges: list[EdgeSpec] = []
    for e in edges:
        flat_edges.extend(e if isinstance(e, (list, tuple)) else [e])
    if mnemonics is None:
        mnemonics = common_mnemonics([m.name for m in memories],
                                     [c.name for c in computes], addr_bits)
    ports = tuple(sorted(
        ((tuple(k), tuple(v)) for k, v in
         (operand_ports.items() if isinstance(operand_ports, dict)
          else operand_ports))))
    return ACGSpec(name=name, memories=memories, computes=computes,
                   edges=tuple(flat_edges), mnemonics=tuple(mnemonics),
                   issue_slots=issue_slots, loop_overhead=loop_overhead,
                   operand_ports=ports)


# ---------------------------------------------------------------------------
# ACG <-> spec conversion
# ---------------------------------------------------------------------------


def build_acg(spec: ACGSpec):
    """Materialize the graph described by ``spec`` (``ACG.from_spec``)."""
    from .acg import ACG, Capability, Field, OperandSpec

    validate_spec(spec)
    g = ACG(spec.name, issue_slots=spec.issue_slots,
            loop_overhead=spec.loop_overhead)
    for m in spec.memories:
        g.add_memory(m.name, m.data_width, m.banks, m.depth, m.offchip)

    def operand(o: Operand) -> OperandSpec:
        return OperandSpec(dt(o[0]), tuple(int(d) for d in o[1:]))

    for c in spec.computes:
        g.add_compute(c.name, [
            Capability(k.name, tuple(operand(i) for i in k.inputs),
                       tuple(operand(o) for o in k.outputs), k.cycles,
                       k.geometry)
            for k in c.capabilities], slot=c.slot)
    for e in spec.edges:
        g.connect(e.src, e.dst, e.bandwidth, e.latency)
    for (node, capname), ports in spec.operand_ports:
        g.operand_ports[(node, capname)] = tuple(ports)
    for m in spec.mnemonics:
        g.define_mnemonic(m.name, m.opcode,
                          [Field(f.name, f.bits, f.enum, f.rw)
                           for f in m.fields], **dict(m.attrs))
    return g


def spec_of(acg) -> ACGSpec:
    """Snapshot a live ACG back into its canonical spec (``acg.to_spec``)."""
    from .acg import MemoryNode

    def operand(o) -> Operand:
        return (o.dtype.name,) + tuple(o.shape)

    memories = tuple(
        MemorySpec(m.name, m.data_width, m.banks, m.depth, m.offchip)
        for m in acg.nodes.values() if isinstance(m, MemoryNode))
    computes = tuple(
        ComputeSpec(c.name, tuple(
            CapabilitySpec(k.name, tuple(operand(o) for o in k.outputs),
                           tuple(operand(i) for i in k.inputs), k.cycles,
                           k.geometry)
            for k in c.capabilities), c.slot)
        for c in acg.nodes.values() if not isinstance(c, MemoryNode))
    edges = tuple(EdgeSpec(e.src, e.dst, e.bandwidth, e.latency)
                  for e in acg.edges)
    mnemonics = tuple(
        MnemonicSpec(m.name, m.opcode,
                     tuple(FieldSpec(f.name, f.bits, f.enum, f.rw)
                           for f in m.fields),
                     tuple(sorted(m.attrs.items())))
        for m in acg.mnemonics.values())
    ports = tuple(sorted((tuple(k), tuple(v))
                         for k, v in acg.operand_ports.items()))
    return ACGSpec(name=acg.name, memories=memories, computes=computes,
                   edges=edges, mnemonics=mnemonics,
                   issue_slots=acg.issue_slots,
                   loop_overhead=acg.loop_overhead, operand_ports=ports)


# ---------------------------------------------------------------------------
# structural validation
# ---------------------------------------------------------------------------


class SpecError(ValueError):
    """A covenant spec is structurally unsound; ``problems`` names each
    issue (the diagnostics contract: no bare KeyErrors)."""

    def __init__(self, spec_name: str, problems: list[str]):
        self.spec_name = spec_name
        self.problems = list(problems)
        bullet = "\n  - ".join(self.problems)
        super().__init__(
            f"invalid covenant spec {spec_name!r}:\n  - {bullet}")


def validate_spec(spec: ACGSpec, *, raise_on_error: bool = True) -> list[str]:
    """Structural checks over a covenant spec.  Returns the problem list
    (empty when sound); raises ``SpecError`` on problems unless
    ``raise_on_error=False``."""
    p: list[str] = []
    if not spec.name:
        p.append("spec has no name")
    names: list[str] = [m.name for m in spec.memories] + \
        [c.name for c in spec.computes]
    dupes = {n for n in names if names.count(n) > 1}
    if dupes:
        p.append(f"duplicate node name(s): {sorted(dupes)}")
    if not spec.memories:
        p.append("no memory nodes (operands need a home)")
    if not spec.computes:
        p.append("no compute nodes (nothing can execute a capability)")
    if spec.issue_slots < 1:
        p.append(f"issue_slots must be >= 1, got {spec.issue_slots}")
    if spec.loop_overhead < 0:
        p.append(f"loop_overhead must be >= 0, got {spec.loop_overhead}")
    for m in spec.memories:
        for field in ("data_width", "banks", "depth"):
            if getattr(m, field) <= 0:
                p.append(f"memory {m.name}: {field} must be positive, "
                         f"got {getattr(m, field)}")
    for c in spec.computes:
        if not c.capabilities:
            p.append(f"compute {c.name}: declares no capabilities")
        for k in c.capabilities:
            if not k.outputs:
                p.append(f"compute {c.name}: capability {k.name} has no "
                         f"outputs")
            for o in list(k.outputs) + list(k.inputs):
                try:
                    dt(o[0])
                except KeyError:
                    p.append(f"compute {c.name}: capability {k.name} uses "
                             f"unknown dtype {o[0]!r}")
                if any(not isinstance(d, int) or d <= 0 for d in o[1:]):
                    p.append(f"compute {c.name}: capability {k.name} operand "
                             f"{o} has a non-positive or non-integer "
                             f"dimension")
            if k.geometry is not None and (
                    len(k.geometry) != 3 or
                    any(not isinstance(g, int) or g <= 0
                        for g in k.geometry)):
                p.append(f"compute {c.name}: capability {k.name} geometry "
                         f"{k.geometry} must be 3 positive integer dims "
                         f"(m, n, k)")
            if k.cycles < 0:
                p.append(f"compute {c.name}: capability {k.name} cycles "
                         f"must be >= 0")
    known = set(names)
    for e in spec.edges:
        for end in (e.src, e.dst):
            if end not in known:
                p.append(f"edge {e.src}->{e.dst}: unknown node {end!r}")
        if e.bandwidth <= 0:
            p.append(f"edge {e.src}->{e.dst}: bandwidth must be positive, "
                     f"got {e.bandwidth}")
        if e.latency < 0:
            p.append(f"edge {e.src}->{e.dst}: latency must be >= 0")
    touched = {e.src for e in spec.edges} | {e.dst for e in spec.edges}
    for c in spec.computes:
        if c.name not in touched:
            p.append(f"compute {c.name}: connected to no edge — no memory "
                     f"can feed it")
    opcodes: dict[int, str] = {}
    mnames: set[str] = set()
    for m in spec.mnemonics:
        if m.name in mnames:
            p.append(f"duplicate mnemonic {m.name!r}")
        mnames.add(m.name)
        if m.opcode in opcodes:
            p.append(f"mnemonic {m.name}: opcode {m.opcode:#x} collides "
                     f"with {opcodes[m.opcode]!r}")
        else:
            opcodes[m.opcode] = m.name
        for f in m.fields:
            if f.bits <= 0:
                p.append(f"mnemonic {m.name}: field {f.name} has "
                         f"non-positive width")
            if f.enum is not None and len(f.enum) > (1 << f.bits):
                p.append(f"mnemonic {m.name}: field {f.name} enumerates "
                         f"{len(f.enum)} values in {f.bits} bits")
            if f.rw not in (None, "r", "w"):
                p.append(f"mnemonic {m.name}: field {f.name} rw must be "
                         f"'r', 'w' or None")
    cap_names = {(c.name, k.name) for c in spec.computes
                 for k in c.capabilities}
    mem_names = {m.name for m in spec.memories}
    for (node, capname), ports in spec.operand_ports:
        if (node, capname) not in cap_names:
            p.append(f"operand_ports ({node}, {capname}): no such "
                     f"capability on that compute node")
        for port in ports:
            if port not in mem_names:
                p.append(f"operand_ports ({node}, {capname}): staging port "
                         f"{port!r} is not a memory node")
    if p and raise_on_error:
        raise SpecError(spec.name or "<unnamed>", p)
    return p


__all__ = [
    "ACGSpec", "BINARY", "CapabilitySpec", "ComputeSpec", "EdgeSpec",
    "FieldSpec", "MemorySpec", "MnemonicSpec", "SpecError", "UNARY",
    "acg_spec", "build_acg", "common_mnemonics", "parse_overrides", "scap",
    "scu", "sedge", "smem", "sop", "spec_of", "validate_spec",
]
