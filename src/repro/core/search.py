"""Search-based schedule optimization (§4's "enabling optimization").

The paper positions Covenant as the substrate that lets Ansor/FlexTensor-
style search run against NEW accelerators: Algorithm 1 prunes the
transformation space to *valid* schedules, and the ACG-aware cost model
replaces on-device measurement.  This module is that loop, as a driver
subsystem:

    space      = Algorithm-1-valid tilings x unroll factors
                 (scheduler.schedule_space)
    candidate  = a schedule *point* injected into the stock pass pipeline
                 via PassContext.overrides — materialisation is exactly
                 ``repro.compile``'s flow, never a private pass chain
    score      = mnemonic-faithful analytic cycles (cost.py)
    strategy   = a registered SearchStrategy: ``beam`` (cost-bound-guided
                 prefix enumeration), ``evolutionary`` (divisor-
                 neighbourhood mutation, transfer-aware), ``random``,
                 ``grid``, ``exhaustive``

Cost-model guidance (the paper's §4 claim that an architecture-faithful
model, not blind enumeration, is what makes search affordable):

* ``beam`` commits tiling decisions loop-by-loop as *prefixes*, scoring
  each partial schedule with ``cost.prefix_bound`` — an admissible lower
  bound (committed loops cost exactly, uncommitted loops at their
  best-case tile) — and pruning to the top ``beam_width`` prefixes per
  level; only surviving complete points are materialised and evaluated.
* ``evolutionary`` mutation is transfer-aware: when a parent's
  ``CostReport`` is transfer-dominated, the mutated loop is drawn from
  the loops of the operand whose staging edges dominate
  ``transfer_cycles`` (``cost.transfer_hot_vars``) instead of uniformly.
* ``SearchOptions(warm_start=True)`` seeds the initial population from
  the best recorded points of same-``ScheduleSpace``-shaped layers in the
  artifact store (``store.WarmStartIndex``, built from the sweep
  journals), so a fleet's measurements accelerate every later search.

Drive it through the compile driver — ``repro.compile(layer, target,
CompileOptions(search=SearchOptions(...)))`` — so searched schedules flow
through the same artifact/cache/store path as heuristic ones; the legacy
``search_schedule`` entry point remains as a thin wrapper.

Determinism: candidate generation and mutation draw from *separate* seeded
streams, so the same (codelet, target, options, seed) always yields an
identical trace and winner regardless of how a strategy interleaves the
two (tests/test_search.py asserts this).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable, Sequence

from . import cost as cost_mod
from .acg import ACG
from .codelet import Codelet
from .pipeline import CompileOptions, PassContext, Pipeline
from .scheduler import ScheduleSpace, schedule_space

# a schedule point: (sorted (var, factor) tiling items, unroll factor)
Point = tuple[tuple, int]


@dataclasses.dataclass(frozen=True)
class SearchOptions:
    """Knobs of one schedule search; hashable + fingerprintable so a
    searched compile is content-addressed like any other.

    ``generations * population`` is every strategy's evaluation budget
    (materialised candidate count) — strategies are budget-comparable by
    construction.  ``beam_width`` is the FLOOR on the ``beam`` strategy's
    per-level prefix survivor count (a larger budget widens the beam so
    every evaluation slot gets a distinct tiling); ``warm_start`` seeds
    the search from the artifact store's best same-shaped recorded points
    (making the result depend on store history as well as the seed);
    ``patience`` stops a strategy after that many consecutive trace
    entries without improvement (``None`` = run the full budget)."""

    strategy: str = "evolutionary"
    generations: int = 6
    population: int = 16
    elite: int = 4
    unroll_choices: tuple = (1, 2, 4, 8)
    seed: int = 0
    max_candidates: int = 2000
    beam_width: int = 8
    warm_start: bool = False
    patience: int | None = None

    def fingerprint(self) -> str:
        return repr(dataclasses.astuple(self))

    @property
    def budget(self) -> int:
        return max(1, self.generations * self.population)


@dataclasses.dataclass
class SearchResult:
    best: Codelet
    best_cycles: float
    heuristic_cycles: float
    evaluated: int
    trace: list                    # (generation, best_cycles_so_far)
    strategy: str = "evolutionary"
    point: dict | None = None      # winning {"tiling", "unroll_factor"};
    #                                None when the heuristic won
    seeded: int = 0                # warm-start seeds injected
    space_sig: str | None = None   # ScheduleSpace shape id (warm-start key)
    best_ctx: PassContext | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def gain(self) -> float:
        """heuristic/best cycle ratio.  Degenerate zero-cycle schedules
        (the seed point already sits at the space optimum) report 0.0
        instead of dividing by zero."""
        if self.best_cycles <= 0.0:
            return 0.0 if self.heuristic_cycles <= 0.0 else float("inf")
        return self.heuristic_cycles / self.best_cycles

    def summary(self) -> dict:
        """JSON-serialisable digest (what the artifact store persists)."""
        return {"strategy": self.strategy, "best_cycles": self.best_cycles,
                "heuristic_cycles": self.heuristic_cycles,
                "evaluated": self.evaluated, "point": self.point,
                "seeded": self.seeded, "space_sig": self.space_sig,
                "trace": [list(t) for t in self.trace]}


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------

# name -> strategy fn(space, opts, evaluate, rng_init, rng_mut,
# seeds=()) -> trace.  ``evaluate(point) -> cycles`` memoises and tracks
# the incumbent (``evaluate.reports`` holds the per-point CostReport for
# transfer-aware operators); ``seeds`` are warm-start points to try first.
# A strategy only decides *which* points to visit and in what order.
StrategyFn = Callable[..., list]
STRATEGIES: dict[str, StrategyFn] = {}


def register_strategy(name: str) -> Callable[[StrategyFn], StrategyFn]:
    def deco(fn: StrategyFn) -> StrategyFn:
        STRATEGIES[name] = fn
        return fn
    return deco


def available_strategies() -> list[str]:
    return sorted(STRATEGIES)


def _tiling_key(tiling: dict) -> tuple:
    return tuple(sorted(tiling.items()))


def _random_point(space: ScheduleSpace, unrolls, rng: random.Random) -> Point:
    tiling = space.tilings[rng.randrange(len(space.tilings))]
    return (_tiling_key(tiling), rng.choice(unrolls))


def _mutate(pt: Point, space: ScheduleSpace, unrolls,
            rng: random.Random, prefer: Sequence[str] = ()) -> Point:
    """Move one loop's tile factor to a neighbouring divisor on its grid
    (staying Algorithm-1-valid), or flip the unroll factor.  ``prefer``
    biases the mutated-loop choice (transfer-aware mutation: the loops of
    the operand dominating ``CostReport.transfer_cycles``); empty means
    uniform."""
    tiling, u = dict(pt[0]), pt[1]
    if rng.random() < 0.5 and tiling:
        pool = [v for v in prefer if v in tiling] or sorted(tiling)
        var = rng.choice(pool)
        grid = space.divisors.get(var, [tiling[var]])
        i = grid.index(tiling[var]) if tiling[var] in grid else 0
        j = min(max(i + rng.choice((-1, 1)), 0), len(grid) - 1)
        cand = dict(tiling, **{var: grid[j]})
        if space.valid(cand):
            tiling = cand
    else:
        u = rng.choice(unrolls)
    return (_tiling_key(tiling), u)


def _hot_vars(space: ScheduleSpace, pt: Point, evaluate,
              cache: dict) -> list[str]:
    """Transfer-aware mutation bias for ``pt``: when its cost report is
    transfer-dominated, the loop vars of the operand whose staging edges
    dominate ``transfer_cycles``; else no bias."""
    if pt in cache:
        return cache[pt]
    hot: list[str] = []
    rep = getattr(evaluate, "reports", {}).get(pt)
    if rep is not None and rep.transfer_cycles > rep.compute_cycles:
        hot = cost_mod.transfer_hot_vars(space.probe, space.acg, space.plans,
                                         dict(pt[0]),
                                         divisors=space.divisors)
    cache[pt] = hot
    return hot


def _stalled(trace: list, patience: int | None) -> bool:
    """True once the last ``patience`` trace entries brought no
    improvement — the convergence early-stop warm-started searches cash
    in (their seeds start at or near the optimum)."""
    if patience is None or len(trace) <= patience:
        return False
    return trace[-1][1] >= trace[-1 - patience][1]


@register_strategy("evolutionary")
def evolutionary(space, opts: SearchOptions, evaluate, rng_init, rng_mut,
                 seeds: Sequence[Point] = ()):
    pop = list(seeds)[:opts.population]
    pop += [_random_point(space, opts.unroll_choices, rng_init)
            for _ in range(opts.population - len(pop))]
    trace, best = [], float("inf")
    hot_cache: dict = {}
    for gen in range(opts.generations):
        scored = sorted(pop, key=evaluate)
        best = min(best, evaluate(scored[0]))
        trace.append((gen, best))
        if _stalled(trace, opts.patience):
            break
        elites = scored[:opts.elite]
        pop = list(elites)
        while len(pop) < opts.population:
            parent = rng_mut.choice(elites)
            pop.append(_mutate(parent, space, opts.unroll_choices, rng_mut,
                               prefer=_hot_vars(space, parent, evaluate,
                                                hot_cache)))
    return trace


def _neighbours(pt: Point, space: ScheduleSpace, unrolls) -> list[Point]:
    """Deterministic divisor-grid neighbourhood of a point: each loop
    stepped one divisor either way (validity-checked), each alternative
    unroll factor."""
    tiling, u = dict(pt[0]), pt[1]
    out: list[Point] = []
    for var in sorted(tiling):
        grid = space.divisors.get(var, [tiling[var]])
        i = grid.index(tiling[var]) if tiling[var] in grid else 0
        for j in (i - 1, i + 1):
            if 0 <= j < len(grid) and grid[j] != tiling[var]:
                cand = dict(tiling, **{var: grid[j]})
                if space.valid(cand):
                    out.append((_tiling_key(cand), u))
    for u2 in sorted(unrolls, reverse=True):
        if u2 != u:
            out.append((pt[0], u2))
    return out


@register_strategy("beam")
def beam(space, opts: SearchOptions, evaluate, rng_init, rng_mut,
         seeds: Sequence[Point] = ()):
    """Cost-bound-guided beam over tiling prefixes.

    Tiling decisions are committed loop-by-loop in nest order; at each
    level every one-factor extension of a surviving prefix is scored with
    ``cost.prefix_bound`` (admissible: committed loops exact, uncommitted
    at their best-case tile) and only the best-bounded prefixes survive
    (at least ``beam_width``).  Only complete schedules that survive every
    level are materialised through the pipeline — ranked best-bound-first
    under the same ``generations * population`` evaluation budget every
    strategy gets; the budget's tail hill-climbs the incumbent's divisor
    neighbourhood (the same moves evolutionary mutation makes, minus the
    dice).  Fully deterministic: no rng draws."""
    order = space.loop_order()
    budget = opts.budget
    unrolls = tuple(opts.unroll_choices) or (1,)
    explore = max(1, budget - budget // 3)   # ranked-candidate phase
    # final survivors: one per explore slot (phase 1 evaluates each
    # surviving tiling once, at the widest unroll); intermediate levels
    # keep twice as many so a mid-rank prefix whose strength only shows
    # once inner loops commit is not cut prematurely
    keep = max(1, opts.beam_width, explore)

    def rank(prefix: tuple) -> tuple:
        # primary: the admissible packed bound the pruning guarantee
        # rests on; secondary: the serial-sum form, which keeps
        # discriminating (via the reload/row floors) when compute
        # dominates the packed max-form and every valid prefix ties
        packed, serial = cost_mod.prefix_bounds(
            space.probe, space.acg, space.plans, space.committed(prefix),
            divisors=space.divisors, max_coalesce=max(unrolls))
        return (packed, serial, prefix)

    prefixes: list[tuple] = [()]
    for depth in range(1, len(order) + 1):
        ext = space.prefixes(depth, within=prefixes)
        width = keep if depth == len(order) else 2 * keep
        prefixes = sorted(ext, key=rank)[:width]
    # complete candidates best-bound-first: every surviving tiling once at
    # the widest unroll (coalescing only ever helps), then the remaining
    # unroll choices; seeds jump the queue
    u_first, *u_rest = sorted(unrolls, reverse=True)
    cands = list(seeds)
    cands += [(_tiling_key(space.committed(p)), u_first) for p in prefixes]
    cands += [(_tiling_key(space.committed(p)), u)
              for p in prefixes for u in u_rest]
    trace: list = []
    chunk = max(1, opts.population)
    state = {"best": float("inf"), "pt": None, "evals": 0}

    def visit(pt: Point) -> None:
        fresh = pt not in getattr(evaluate, "cache", {})
        cyc = evaluate(pt)
        if cyc < state["best"]:
            state["best"], state["pt"] = cyc, pt
        if fresh:
            state["evals"] += 1
            if state["evals"] % chunk == 0:
                trace.append((state["evals"] // chunk - 1, state["best"]))

    def exhausted(limit: int) -> bool:
        return state["evals"] >= limit or _stalled(trace, opts.patience)

    for pt in cands:
        if exhausted(explore):
            break
        visit(pt)
    improved = True
    while improved and state["pt"] is not None and not exhausted(budget):
        improved = False
        for npt in _neighbours(state["pt"], space, unrolls):
            if exhausted(budget):
                break
            before = state["best"]
            visit(npt)
            if state["best"] < before:
                improved = True
    for pt in cands:                     # leftover budget: keep exploring
        if exhausted(budget):
            break
        visit(pt)
    if not trace or trace[-1][1] != state["best"] or state["evals"] % chunk:
        trace.append((max(0, (state["evals"] + chunk - 1) // chunk - 1),
                      state["best"]))
    return trace


@register_strategy("random")
def random_search(space, opts: SearchOptions, evaluate, rng_init, rng_mut,
                  seeds: Sequence[Point] = ()):
    # seeds replace (not add to) first-generation draws, so the
    # generations*population budget contract holds for warm starts too
    trace, best = [], float("inf")
    pending = list(seeds)[:opts.population]
    for gen in range(opts.generations):
        for _ in range(opts.population - len(pending)):
            pending.append(_random_point(space, opts.unroll_choices,
                                         rng_init))
        for pt in pending:
            best = min(best, evaluate(pt))
        pending = []
        trace.append((gen, best))
        if _stalled(trace, opts.patience):
            break
    return trace


@register_strategy("grid")
def grid_search(space, opts: SearchOptions, evaluate, rng_init, rng_mut,
                seeds: Sequence[Point] = ()):
    """Evenly strided sweep of tilings x unrolls within the same
    generations*population evaluation budget as the other strategies."""
    budget = opts.budget
    points = [(_tiling_key(t), u) for t in space.tilings
              for u in opts.unroll_choices]
    stride = max(1, len(points) // budget)
    chosen = points[::stride][:budget]
    trace, best = [], float("inf")
    chunk = max(1, len(chosen) // max(opts.generations, 1))
    for gen in range(0, len(chosen), chunk):
        for pt in chosen[gen:gen + chunk]:
            best = min(best, evaluate(pt))
        trace.append((gen // chunk, best))
    return trace


@register_strategy("exhaustive")
def exhaustive(space, opts: SearchOptions, evaluate, rng_init, rng_mut,
               seeds: Sequence[Point] = ()):
    """Every enumerated tiling x every unroll choice (the space is already
    capped by SearchOptions.max_candidates)."""
    trace, best = [], float("inf")
    for gi, t in enumerate(space.tilings):
        for u in opts.unroll_choices:
            best = min(best, evaluate((_tiling_key(t), u)))
        if gi % 50 == 0 or gi == len(space.tilings) - 1:
            trace.append((gi, best))
    return trace


# ---------------------------------------------------------------------------
# candidate materialisation — through the pipeline, not a private pass chain
# ---------------------------------------------------------------------------


def materialise(cdlt: Codelet, acg: ACG, pipeline: Pipeline,
                options: CompileOptions, point: dict | None) -> PassContext:
    """Run the full compile pipeline (codegen deferred) with the schedule
    point injected as pass-input data; ``point=None`` is the stock
    heuristic flow.  Covenant validation depends only on (codelet, acg,
    options) — never on the injected point — so candidate
    materialisations skip it: the heuristic baseline already validated
    this pairing once."""
    skip = ("codegen",) if point is None else ("codegen", "covenant")
    ctx = PassContext(cdlt.clone(), acg, options,
                      overrides=dict(point) if point else {})
    pipeline.run(ctx, skip=skip)
    return ctx


def _score(ctx: PassContext) -> "cost_mod.CostReport":
    pack = ctx.state.get("pack", ctx.options.pack)
    return cost_mod.cost(ctx.cdlt, ctx.acg, pack=pack)


def _rng_streams(seed: int) -> tuple[random.Random, random.Random]:
    """Separate seeded streams for candidate generation vs mutation: the
    trace must not depend on how a strategy interleaves the two."""
    return random.Random(seed), random.Random(seed ^ 0x9E3779B9)


def _warm_seeds(space: ScheduleSpace, sopts: SearchOptions,
                store) -> list[Point]:
    """Warm-start seed points for this space from the store's recorded
    best points (same-shaped layers first), capped at half the
    population so cold exploration still happens."""
    from . import store as store_mod

    st = store_mod.resolve(store)
    if st is None:
        return []
    index = store_mod.WarmStartIndex.cached_for(st)
    limit = max(1, sopts.population // 2)
    seeds = []
    for tiling, unroll in index.seeds(space, sopts.unroll_choices,
                                      limit=limit):
        seeds.append((_tiling_key(tiling), unroll))
    return seeds


def _call_strategy(fn: StrategyFn, space, sopts, evaluate, rng_init,
                   rng_mut, seeds: Sequence[Point]):
    """Invoke a strategy, passing ``seeds`` only if it takes them (user-
    registered strategies predating warm-start keep working)."""
    import inspect

    try:
        params = inspect.signature(fn).parameters
        takes_seeds = "seeds" in params or any(
            p.kind is inspect.Parameter.VAR_KEYWORD
            for p in params.values())
    except (TypeError, ValueError):
        takes_seeds = False
    if takes_seeds:
        return fn(space, sopts, evaluate, rng_init, rng_mut, seeds=seeds)
    return fn(space, sopts, evaluate, rng_init, rng_mut)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def search_schedule(cdlt: Codelet, acg: ACG, *,
                    options: CompileOptions | None = None,
                    pipeline: Pipeline | None = None,
                    store=None,
                    **overrides) -> SearchResult:
    """Search the valid schedule space of ``cdlt`` on ``acg``.

    ``options`` is a ``CompileOptions`` whose ``search`` field (or
    ``SearchOptions()``) selects the strategy/budget; keyword overrides
    (``generations=4, seed=1, strategy="grid", ...``) tweak it — the legacy
    call style.  ``store`` (an ``ArtifactStore``/path, defaulting to
    ``options.store``) is only consulted when ``warm_start=True``: the
    initial population is seeded from its best recorded same-shaped
    points.  Never returns a schedule worse than the heuristic.
    """
    opts = options if options is not None else CompileOptions()
    if opts.search is not None and not isinstance(opts.search, SearchOptions):
        raise TypeError(f"CompileOptions.search must be a SearchOptions, "
                        f"got {type(opts.search)!r}")
    sopts = opts.search if opts.search is not None else SearchOptions()
    if overrides:
        sopts = dataclasses.replace(sopts, **overrides)
    if sopts.strategy not in STRATEGIES:
        raise KeyError(f"unknown search strategy {sopts.strategy!r}; "
                       f"registered: {available_strategies()}")
    pl = pipeline if pipeline is not None \
        else Pipeline.default().with_acg_hooks(acg)

    space = schedule_space(cdlt, acg, options=opts, pipeline=pl,
                           max_candidates=sopts.max_candidates)
    assert space.tilings, f"no valid tilings for {cdlt.name} on {acg.name}"

    heur_ctx = materialise(cdlt, acg, pl, opts, None)
    heur_cycles = _score(heur_ctx).cycles

    evaluated: dict[Point, float] = {}
    reports: dict[Point, "cost_mod.CostReport"] = {}
    incumbent: list = [None, float("inf")]  # [point, cycles]

    def evaluate(pt: Point) -> float:
        if pt in evaluated:
            return evaluated[pt]
        try:
            ctx = materialise(cdlt, acg, pl, opts,
                              {"tiling": dict(pt[0]), "unroll_factor": pt[1]})
            rep = _score(ctx)
            cyc = rep.cycles
            reports[pt] = rep
        except Exception:
            cyc = float("inf")
        evaluated[pt] = cyc
        if cyc < incumbent[1]:
            incumbent[0], incumbent[1] = pt, cyc
        return cyc

    evaluate.cache = evaluated    # strategies dedup against the memo
    evaluate.reports = reports    # transfer-aware operators read these

    seeds: list[Point] = []
    if sopts.warm_start:
        seeds = _warm_seeds(space, sopts,
                            store if store is not None else opts.store)

    rng_init, rng_mut = _rng_streams(sopts.seed)
    trace = _call_strategy(STRATEGIES[sopts.strategy], space, sopts,
                           evaluate, rng_init, rng_mut, tuple(seeds))

    best_pt, best_cyc = incumbent
    if best_pt is not None and best_cyc < heur_cycles:
        point = {"tiling": dict(best_pt[0]), "unroll_factor": best_pt[1]}
        ctx = materialise(cdlt, acg, pl, opts, point)
        ctx.cdlt.note(f"search[{sopts.strategy}]: tiling={point['tiling']} "
                      f"unroll={point['unroll_factor']} "
                      f"cycles={best_cyc:.0f} (heuristic {heur_cycles:.0f})")
    else:
        ctx, best_cyc, point = heur_ctx, heur_cycles, None
    return SearchResult(best=ctx.cdlt, best_cycles=best_cyc,
                        heuristic_cycles=heur_cycles,
                        evaluated=len(evaluated), trace=trace,
                        strategy=sopts.strategy, point=point,
                        seeded=len(seeds), space_sig=space.signature(),
                        best_ctx=ctx)


__all__ = ["STRATEGIES", "SearchOptions", "SearchResult",
           "available_strategies", "materialise", "register_strategy",
           "search_schedule"]
