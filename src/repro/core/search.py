"""Search-based schedule optimization (§4's "enabling optimization").

The paper positions Covenant as the substrate that lets Ansor/FlexTensor-
style search run against NEW accelerators: Algorithm 1 prunes the
transformation space to *valid* schedules, and the ACG-aware cost model
replaces on-device measurement.  This module is that loop:

    candidates = valid tilings (Algorithm 1)  x  unroll factors
    score      = mnemonic-faithful analytic cycles (cost.py)
    search     = evolutionary: seed with the default heuristic schedule,
                 mutate tile factors / unroll, keep the elite set.

``search_schedule`` returns the best Codelet found plus the search trace;
on the paper benchmarks it beats the one-shot heuristic whenever the
heuristic's greedy tile choice is off the cost-model optimum
(tests/test_search.py, benchmarks fig12 "+search" row).
"""
from __future__ import annotations

import dataclasses
import math
import random

from . import cost as cost_mod
from .acg import ACG
from .codelet import Codelet
from .scheduler import (ScheduleConfig, enumerate_tilings, map_compute,
                        place_operands, plan_operands, validate_tiling)


@dataclasses.dataclass
class SearchResult:
    best: Codelet
    best_cycles: float
    heuristic_cycles: float
    evaluated: int
    trace: list  # (generation, best_cycles)

    @property
    def gain(self) -> float:
        return self.heuristic_cycles / max(self.best_cycles, 1e-9)


def _materialise(cdlt: Codelet, acg: ACG, tiling: dict, unroll: int,
                 pack: bool = True) -> Codelet:
    """Build the full schedule for a given (tiling, unroll) point."""
    from . import passes
    from .scheduler import insert_transfers, split_loops

    c = cdlt.clone()
    place_operands(c, acg)
    map_compute(c, acg, vectorize=True)
    split_loops(c, tiling)
    plans = plan_operands(c, acg)
    insert_transfers(c, acg, plans)
    passes.granularize(c, acg)
    passes.vectorize(c, acg)
    if unroll > 1:
        passes.unroll(c, acg, unroll)
    return c


def _score(c: Codelet, acg: ACG, pack: bool = True) -> float:
    return cost_mod.cost(c, acg, pack=pack).cycles


def search_schedule(cdlt: Codelet, acg: ACG, *, generations: int = 6,
                    population: int = 16, elite: int = 4,
                    unroll_choices=(1, 2, 4, 8), seed: int = 0,
                    max_candidates: int = 2000) -> SearchResult:
    """Evolutionary search over Algorithm-1-valid tilings x unroll factors."""
    from .scheduler import schedule as heuristic_schedule

    rng = random.Random(seed)
    # candidate space (validity via Algorithm 1)
    probe = cdlt.clone()
    place_operands(probe, acg)
    map_compute(probe, acg, vectorize=True)
    plans = plan_operands(probe, acg)
    tilings = enumerate_tilings(probe, acg, plans,
                                max_candidates=max_candidates)
    if not tilings:
        tilings = enumerate_tilings(probe, acg, plans,
                                    max_candidates=max_candidates,
                                    pad_align=True)
    assert tilings, f"no valid tilings for {cdlt.name} on {acg.name}"

    heur = heuristic_schedule(cdlt, acg, ScheduleConfig())
    heur_cycles = _score(heur, acg)

    def random_point():
        return (rng.randrange(len(tilings)), rng.choice(unroll_choices))

    def mutate(pt):
        ti, u = pt
        if rng.random() < 0.5:
            # move one loop's tile factor to a neighbouring divisor
            ti = min(max(ti + rng.choice((-1, 1, -3, 3)), 0),
                     len(tilings) - 1)
        else:
            u = rng.choice(unroll_choices)
        return ti, u

    evaluated = {}

    def evaluate(pt):
        if pt in evaluated:
            return evaluated[pt]
        ti, u = pt
        try:
            c = _materialise(cdlt, acg, tilings[ti], u)
            cyc = _score(c, acg)
        except Exception:
            cyc = float("inf")
        evaluated[pt] = cyc
        return cyc

    pop = [random_point() for _ in range(population)]
    trace = []
    best_pt, best_cyc = None, float("inf")
    for gen in range(generations):
        scored = sorted(pop, key=evaluate)
        if evaluate(scored[0]) < best_cyc:
            best_pt, best_cyc = scored[0], evaluate(scored[0])
        trace.append((gen, best_cyc))
        elites = scored[:elite]
        pop = list(elites)
        while len(pop) < population:
            pop.append(mutate(rng.choice(elites)))

    if best_cyc < heur_cycles:
        best = _materialise(cdlt, acg, tilings[best_pt[0]], best_pt[1])
        best.note(f"search: tiling={tilings[best_pt[0]]} "
                  f"unroll={best_pt[1]} cycles={best_cyc:.0f} "
                  f"(heuristic {heur_cycles:.0f})")
    else:
        best, best_cyc = heur, heur_cycles
    return SearchResult(best, best_cyc, heur_cycles, len(evaluated), trace)


__all__ = ["SearchResult", "search_schedule"]
