"""Search-based schedule optimization (§4's "enabling optimization").

The paper positions Covenant as the substrate that lets Ansor/FlexTensor-
style search run against NEW accelerators: Algorithm 1 prunes the
transformation space to *valid* schedules, and the ACG-aware cost model
replaces on-device measurement.  This module is that loop, as a driver
subsystem:

    space      = Algorithm-1-valid tilings x unroll factors
                 (scheduler.schedule_space)
    candidate  = a schedule *point* injected into the stock pass pipeline
                 via PassContext.overrides — materialisation is exactly
                 ``repro.compile``'s flow, never a private pass chain
    score      = mnemonic-faithful analytic cycles (cost.py)
    strategy   = a registered SearchStrategy: ``evolutionary`` (divisor-
                 neighbourhood mutation), ``random``, ``grid``,
                 ``exhaustive``

Drive it through the compile driver — ``repro.compile(layer, target,
CompileOptions(search=SearchOptions(...)))`` — so searched schedules flow
through the same artifact/cache/store path as heuristic ones; the legacy
``search_schedule`` entry point remains as a thin wrapper.

Determinism: candidate generation and mutation draw from *separate* seeded
streams, so the same (codelet, target, options, seed) always yields an
identical trace and winner regardless of how a strategy interleaves the
two (tests/test_search.py asserts this).
"""
from __future__ import annotations

import dataclasses
import random
from typing import Callable

from . import cost as cost_mod
from .acg import ACG
from .codelet import Codelet
from .pipeline import CompileOptions, PassContext, Pipeline
from .scheduler import ScheduleSpace, schedule_space

# a schedule point: (sorted (var, factor) tiling items, unroll factor)
Point = tuple[tuple, int]


@dataclasses.dataclass(frozen=True)
class SearchOptions:
    """Knobs of one schedule search; hashable + fingerprintable so a
    searched compile is content-addressed like any other."""

    strategy: str = "evolutionary"
    generations: int = 6
    population: int = 16
    elite: int = 4
    unroll_choices: tuple = (1, 2, 4, 8)
    seed: int = 0
    max_candidates: int = 2000

    def fingerprint(self) -> str:
        return repr(dataclasses.astuple(self))


@dataclasses.dataclass
class SearchResult:
    best: Codelet
    best_cycles: float
    heuristic_cycles: float
    evaluated: int
    trace: list                    # (generation, best_cycles_so_far)
    strategy: str = "evolutionary"
    point: dict | None = None      # winning {"tiling", "unroll_factor"};
    #                                None when the heuristic won
    best_ctx: PassContext | None = dataclasses.field(
        default=None, repr=False, compare=False)

    @property
    def gain(self) -> float:
        return self.heuristic_cycles / max(self.best_cycles, 1e-9)

    def summary(self) -> dict:
        """JSON-serialisable digest (what the artifact store persists)."""
        return {"strategy": self.strategy, "best_cycles": self.best_cycles,
                "heuristic_cycles": self.heuristic_cycles,
                "evaluated": self.evaluated, "point": self.point,
                "trace": [list(t) for t in self.trace]}


# ---------------------------------------------------------------------------
# strategy registry
# ---------------------------------------------------------------------------

# name -> strategy fn(space, opts, evaluate, rng_init, rng_mut) -> trace.
# ``evaluate(point) -> cycles`` memoises and tracks the incumbent; a
# strategy only decides *which* points to visit and in what order.
StrategyFn = Callable[..., list]
STRATEGIES: dict[str, StrategyFn] = {}


def register_strategy(name: str) -> Callable[[StrategyFn], StrategyFn]:
    def deco(fn: StrategyFn) -> StrategyFn:
        STRATEGIES[name] = fn
        return fn
    return deco


def available_strategies() -> list[str]:
    return sorted(STRATEGIES)


def _tiling_key(tiling: dict) -> tuple:
    return tuple(sorted(tiling.items()))


def _random_point(space: ScheduleSpace, unrolls, rng: random.Random) -> Point:
    tiling = space.tilings[rng.randrange(len(space.tilings))]
    return (_tiling_key(tiling), rng.choice(unrolls))


def _mutate(pt: Point, space: ScheduleSpace, unrolls,
            rng: random.Random) -> Point:
    """Move one loop's tile factor to a neighbouring divisor on its grid
    (staying Algorithm-1-valid), or flip the unroll factor."""
    tiling, u = dict(pt[0]), pt[1]
    if rng.random() < 0.5 and tiling:
        var = rng.choice(sorted(tiling))
        grid = space.divisors.get(var, [tiling[var]])
        i = grid.index(tiling[var]) if tiling[var] in grid else 0
        j = min(max(i + rng.choice((-1, 1)), 0), len(grid) - 1)
        cand = dict(tiling, **{var: grid[j]})
        if space.valid(cand):
            tiling = cand
    else:
        u = rng.choice(unrolls)
    return (_tiling_key(tiling), u)


@register_strategy("evolutionary")
def evolutionary(space, opts: SearchOptions, evaluate, rng_init, rng_mut):
    pop = [_random_point(space, opts.unroll_choices, rng_init)
           for _ in range(opts.population)]
    trace, best = [], float("inf")
    for gen in range(opts.generations):
        scored = sorted(pop, key=evaluate)
        best = min(best, evaluate(scored[0]))
        trace.append((gen, best))
        elites = scored[:opts.elite]
        pop = list(elites)
        while len(pop) < opts.population:
            pop.append(_mutate(rng_mut.choice(elites), space,
                               opts.unroll_choices, rng_mut))
    return trace


@register_strategy("random")
def random_search(space, opts: SearchOptions, evaluate, rng_init, rng_mut):
    trace, best = [], float("inf")
    for gen in range(opts.generations):
        for _ in range(opts.population):
            best = min(best, evaluate(
                _random_point(space, opts.unroll_choices, rng_init)))
        trace.append((gen, best))
    return trace


@register_strategy("grid")
def grid_search(space, opts: SearchOptions, evaluate, rng_init, rng_mut):
    """Evenly strided sweep of tilings x unrolls within the same
    generations*population evaluation budget as the other strategies."""
    budget = max(1, opts.generations * opts.population)
    points = [(_tiling_key(t), u) for t in space.tilings
              for u in opts.unroll_choices]
    stride = max(1, len(points) // budget)
    chosen = points[::stride][:budget]
    trace, best = [], float("inf")
    chunk = max(1, len(chosen) // max(opts.generations, 1))
    for gen in range(0, len(chosen), chunk):
        for pt in chosen[gen:gen + chunk]:
            best = min(best, evaluate(pt))
        trace.append((gen // chunk, best))
    return trace


@register_strategy("exhaustive")
def exhaustive(space, opts: SearchOptions, evaluate, rng_init, rng_mut):
    """Every enumerated tiling x every unroll choice (the space is already
    capped by SearchOptions.max_candidates)."""
    trace, best = [], float("inf")
    for gi, t in enumerate(space.tilings):
        for u in opts.unroll_choices:
            best = min(best, evaluate((_tiling_key(t), u)))
        if gi % 50 == 0 or gi == len(space.tilings) - 1:
            trace.append((gi, best))
    return trace


# ---------------------------------------------------------------------------
# candidate materialisation — through the pipeline, not a private pass chain
# ---------------------------------------------------------------------------


def materialise(cdlt: Codelet, acg: ACG, pipeline: Pipeline,
                options: CompileOptions, point: dict | None) -> PassContext:
    """Run the full compile pipeline (codegen deferred) with the schedule
    point injected as pass-input data; ``point=None`` is the stock
    heuristic flow.  Covenant validation depends only on (codelet, acg,
    options) — never on the injected point — so candidate
    materialisations skip it: the heuristic baseline already validated
    this pairing once."""
    skip = ("codegen",) if point is None else ("codegen", "covenant")
    ctx = PassContext(cdlt.clone(), acg, options,
                      overrides=dict(point) if point else {})
    pipeline.run(ctx, skip=skip)
    return ctx


def _score(ctx: PassContext) -> float:
    pack = ctx.state.get("pack", ctx.options.pack)
    return cost_mod.cost(ctx.cdlt, ctx.acg, pack=pack).cycles


def _rng_streams(seed: int) -> tuple[random.Random, random.Random]:
    """Separate seeded streams for candidate generation vs mutation: the
    trace must not depend on how a strategy interleaves the two."""
    return random.Random(seed), random.Random(seed ^ 0x9E3779B9)


# ---------------------------------------------------------------------------
# entry point
# ---------------------------------------------------------------------------


def search_schedule(cdlt: Codelet, acg: ACG, *,
                    options: CompileOptions | None = None,
                    pipeline: Pipeline | None = None,
                    **overrides) -> SearchResult:
    """Search the valid schedule space of ``cdlt`` on ``acg``.

    ``options`` is a ``CompileOptions`` whose ``search`` field (or
    ``SearchOptions()``) selects the strategy/budget; keyword overrides
    (``generations=4, seed=1, strategy="grid", ...``) tweak it — the legacy
    call style.  Never returns a schedule worse than the heuristic.
    """
    opts = options if options is not None else CompileOptions()
    if opts.search is not None and not isinstance(opts.search, SearchOptions):
        raise TypeError(f"CompileOptions.search must be a SearchOptions, "
                        f"got {type(opts.search)!r}")
    sopts = opts.search if opts.search is not None else SearchOptions()
    if overrides:
        sopts = dataclasses.replace(sopts, **overrides)
    if sopts.strategy not in STRATEGIES:
        raise KeyError(f"unknown search strategy {sopts.strategy!r}; "
                       f"registered: {available_strategies()}")
    pl = pipeline if pipeline is not None \
        else Pipeline.default().with_acg_hooks(acg)

    space = schedule_space(cdlt, acg, options=opts, pipeline=pl,
                           max_candidates=sopts.max_candidates)
    assert space.tilings, f"no valid tilings for {cdlt.name} on {acg.name}"

    heur_ctx = materialise(cdlt, acg, pl, opts, None)
    heur_cycles = _score(heur_ctx)

    evaluated: dict[Point, float] = {}
    incumbent: list = [None, float("inf")]  # [point, cycles]

    def evaluate(pt: Point) -> float:
        if pt in evaluated:
            return evaluated[pt]
        try:
            ctx = materialise(cdlt, acg, pl, opts,
                              {"tiling": dict(pt[0]), "unroll_factor": pt[1]})
            cyc = _score(ctx)
        except Exception:
            cyc = float("inf")
        evaluated[pt] = cyc
        if cyc < incumbent[1]:
            incumbent[0], incumbent[1] = pt, cyc
        return cyc

    rng_init, rng_mut = _rng_streams(sopts.seed)
    trace = STRATEGIES[sopts.strategy](space, sopts, evaluate,
                                       rng_init, rng_mut)

    best_pt, best_cyc = incumbent
    if best_pt is not None and best_cyc < heur_cycles:
        point = {"tiling": dict(best_pt[0]), "unroll_factor": best_pt[1]}
        ctx = materialise(cdlt, acg, pl, opts, point)
        ctx.cdlt.note(f"search[{sopts.strategy}]: tiling={point['tiling']} "
                      f"unroll={point['unroll_factor']} "
                      f"cycles={best_cyc:.0f} (heuristic {heur_cycles:.0f})")
    else:
        ctx, best_cyc, point = heur_ctx, heur_cycles, None
    return SearchResult(best=ctx.cdlt, best_cycles=best_cyc,
                        heuristic_cycles=heur_cycles,
                        evaluated=len(evaluated), trace=trace,
                        strategy=sopts.strategy, point=point, best_ctx=ctx)


__all__ = ["STRATEGIES", "SearchOptions", "SearchResult",
           "available_strategies", "materialise", "register_strategy",
           "search_schedule"]
