"""Analytic cycle model over scheduled Codelets.

The model walks the loop tree bottom-up and is *mnemonic-faithful*: its unit
costs are exactly what the stream simulator charges per mnemonic, so on
streams small enough to execute instruction-by-instruction the two agree
(tested).  Per-op costs:

* transfer of ``bits`` over edge ``e`` staged in rows of ``row_bits``:
  ``ceil(bits / min(coalesce*row_bits, e.bandwidth)) * e.latency`` cycles on
  the ``mem`` slot class — without unrolling each XFER mnemonic carries one
  contiguous row (Fig 8b's "Using only 25% of bandwidth!"); unrolling
  coalesces rows up to the edge bandwidth (§4);
* compute invocation: ``capability.cycles`` on the node's slot class;
* loop iteration: ``acg.loop_overhead`` cycles on the ``ctrl`` class
  (0 on targets with hardware loop sequencers, e.g. DNNWeaver).

With packing enabled (VLIW targets), each loop body's per-iteration cost is
the modulo-scheduling initiation-interval bound from ``passes.pack_body``;
without packing, costs sum serially.
"""
from __future__ import annotations

import dataclasses
import math

from .acg import ACG
from .codelet import Codelet, Compute, Loop, Transfer
from .passes import pack_body


@dataclasses.dataclass
class CostReport:
    cycles: float
    compute_cycles: float
    transfer_cycles: float
    overhead_cycles: float
    compute_invocations: int
    transfer_mnemonics: int
    macs: float = 0.0

    @property
    def breakdown(self) -> str:
        return (f"{self.cycles:.0f} cyc (compute {self.compute_cycles:.0f}, "
                f"mem {self.transfer_cycles:.0f}, ctrl {self.overhead_cycles:.0f})")


def transfer_cost(cdlt: Codelet, t: Transfer, acg: ACG) -> tuple[float, int]:
    """(cycles, n_mnemonics) for one execution of a transfer op.

    Uses the same 2-D DMA burst plan the code generator emits
    (``codegen.xfer_chunks``), so analytic and stream-simulated cycle
    counts agree exactly on unrollable streams.
    """
    from .codegen import xfer_chunks  # local import: codegen imports codelet

    if not t.src.var and t.fill is not None:
        return 0.0, 0  # accumulator alloc: psums reset in-unit
    if t.dst_loc is not None:
        src_loc = cdlt.surrogates[t.src.var].loc
        dst_loc = t.dst_loc
    else:
        src_loc = cdlt.surrogates[t.src.var].loc
        dst_loc = cdlt.surrogates[t.dst.var].loc
    e = acg.edge(src_loc, dst_loc)
    s = cdlt.surrogates[t.src.var] if t.src.var else cdlt.surrogates[t.dst.var]
    rows = math.prod(t.sizes[:-1]) if len(t.sizes) > 1 else 1
    row_bits = t.sizes[-1] * s.dtype.bits
    coalesce = getattr(t, "coalesce", 1)
    n, _, _ = xfer_chunks(rows, row_bits, coalesce, e.bandwidth)
    return float(n * e.latency), n


def _compute_slot(op: Compute, acg: ACG) -> str:
    return acg.compute(op.loc).slot or "exec"


def cost(cdlt: Codelet, acg: ACG, pack: bool = True) -> CostReport:
    """Analytic cycles for one execution of the scheduled codelet."""
    totals = dict(compute=0.0, mem=0.0, ctrl=0.0, invocations=0, xfers=0)

    def body_cost(body: list, trips_ctx: float,
                  loop_ctrl: float = 0.0) -> float:
        """Cost of one iteration of ``body``; ``loop_ctrl`` is the enclosing
        loop's per-iteration bookkeeping, which packs with this body."""
        ops_meta: list[tuple[str, float]] = []
        if loop_ctrl:
            ops_meta.append(("ctrl", loop_ctrl))
            totals["ctrl"] += loop_ctrl * trips_ctx
        serial_children = 0.0
        for item in body:
            if isinstance(item, Loop):
                child = body_cost(item.body, trips_ctx * item.trips,
                                  float(acg.loop_overhead))
                serial_children += child * item.trips
            elif isinstance(item, Transfer):
                cyc, n = transfer_cost(cdlt, item, acg)
                ops_meta.append(("mem", cyc))
                totals["mem"] += cyc * trips_ctx
                totals["xfers"] += int(n * trips_ctx)
            elif isinstance(item, Compute):
                cyc = item.cap_obj.cycles if item.cap_obj else 1
                ops_meta.append((_compute_slot(item, acg), float(cyc)))
                totals["compute"] += cyc * trips_ctx
                totals["invocations"] += int(trips_ctx)
        if pack and acg.issue_slots > 1:
            own = pack_body(ops_meta, acg)
        else:
            own = sum(c for _, c in ops_meta)
        return own + serial_children

    cycles = body_cost(cdlt.body, 1.0)
    return CostReport(
        cycles=cycles,
        compute_cycles=totals["compute"],
        transfer_cycles=totals["mem"],
        overhead_cycles=totals["ctrl"],
        compute_invocations=totals["invocations"],
        transfer_mnemonics=totals["xfers"],
    )


__all__ = ["CostReport", "cost", "transfer_cost"]
