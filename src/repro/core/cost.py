"""Analytic cycle model over scheduled Codelets.

The model walks the loop tree bottom-up and is *mnemonic-faithful*: its unit
costs are exactly what the stream simulator charges per mnemonic, so on
streams small enough to execute instruction-by-instruction the two agree
(tested).  Per-op costs:

* transfer of ``bits`` over edge ``e`` staged in rows of ``row_bits``:
  ``ceil(bits / min(coalesce*row_bits, e.bandwidth)) * e.latency`` cycles on
  the ``mem`` slot class — without unrolling each XFER mnemonic carries one
  contiguous row (Fig 8b's "Using only 25% of bandwidth!"); unrolling
  coalesces rows up to the edge bandwidth (§4);
* compute invocation: ``capability.cycles`` on the node's slot class;
* loop iteration: ``acg.loop_overhead`` cycles on the ``ctrl`` class
  (0 on targets with hardware loop sequencers, e.g. DNNWeaver).

With packing enabled (VLIW targets), each loop body's per-iteration cost is
the modulo-scheduling initiation-interval bound from ``passes.pack_body``;
without packing, costs sum serially.
"""
from __future__ import annotations

import dataclasses
import itertools
import math

from .acg import ACG
from .codelet import Codelet, Compute, Loop, Ref, Transfer
from .passes import DEFAULT_SLOT_CAPACITY, pack_body


@dataclasses.dataclass
class CostReport:
    cycles: float
    compute_cycles: float
    transfer_cycles: float
    overhead_cycles: float
    compute_invocations: int
    transfer_mnemonics: int
    macs: float = 0.0

    @property
    def breakdown(self) -> str:
        return (f"{self.cycles:.0f} cyc (compute {self.compute_cycles:.0f}, "
                f"mem {self.transfer_cycles:.0f}, ctrl {self.overhead_cycles:.0f})")


def transfer_cost(cdlt: Codelet, t: Transfer, acg: ACG) -> tuple[float, int]:
    """(cycles, n_mnemonics) for one execution of a transfer op.

    Uses the same 2-D DMA burst plan the code generator emits
    (``codegen.xfer_chunks``), so analytic and stream-simulated cycle
    counts agree exactly on unrollable streams.
    """
    from .codegen import xfer_chunks  # local import: codegen imports codelet

    if not t.src.var and t.fill is not None:
        return 0.0, 0  # accumulator alloc: psums reset in-unit
    if t.dst_loc is not None:
        src_loc = cdlt.surrogates[t.src.var].loc
        dst_loc = t.dst_loc
    else:
        src_loc = cdlt.surrogates[t.src.var].loc
        dst_loc = cdlt.surrogates[t.dst.var].loc
    e = acg.edge(src_loc, dst_loc)
    s = cdlt.surrogates[t.src.var] if t.src.var else cdlt.surrogates[t.dst.var]
    rows = math.prod(t.sizes[:-1]) if len(t.sizes) > 1 else 1
    row_bits = t.sizes[-1] * s.dtype.bits
    coalesce = getattr(t, "coalesce", 1)
    n, _, _ = xfer_chunks(rows, row_bits, coalesce, e.bandwidth)
    return float(n * e.latency), n


def _compute_slot(op: Compute, acg: ACG) -> str:
    return acg.compute(op.loc).slot or "exec"


def cost(cdlt: Codelet, acg: ACG, pack: bool = True) -> CostReport:
    """Analytic cycles for one execution of the scheduled codelet."""
    totals = dict(compute=0.0, mem=0.0, ctrl=0.0, invocations=0, xfers=0)

    def body_cost(body: list, trips_ctx: float,
                  loop_ctrl: float = 0.0) -> float:
        """Cost of one iteration of ``body``; ``loop_ctrl`` is the enclosing
        loop's per-iteration bookkeeping, which packs with this body."""
        ops_meta: list[tuple[str, float]] = []
        if loop_ctrl:
            ops_meta.append(("ctrl", loop_ctrl))
            totals["ctrl"] += loop_ctrl * trips_ctx
        serial_children = 0.0
        for item in body:
            if isinstance(item, Loop):
                child = body_cost(item.body, trips_ctx * item.trips,
                                  float(acg.loop_overhead))
                serial_children += child * item.trips
            elif isinstance(item, Transfer):
                cyc, n = transfer_cost(cdlt, item, acg)
                ops_meta.append(("mem", cyc))
                totals["mem"] += cyc * trips_ctx
                totals["xfers"] += int(n * trips_ctx)
            elif isinstance(item, Compute):
                cyc = item.cap_obj.cycles if item.cap_obj else 1
                ops_meta.append((_compute_slot(item, acg), float(cyc)))
                totals["compute"] += cyc * trips_ctx
                totals["invocations"] += int(trips_ctx)
        if pack and acg.issue_slots > 1:
            own = pack_body(ops_meta, acg)
        else:
            own = sum(c for _, c in ops_meta)
        return own + serial_children

    cycles = body_cost(cdlt.body, 1.0)
    return CostReport(
        cycles=cycles,
        compute_cycles=totals["compute"],
        transfer_cycles=totals["mem"],
        overhead_cycles=totals["ctrl"],
        compute_invocations=totals["invocations"],
        transfer_mnemonics=totals["xfers"],
    )


# ---------------------------------------------------------------------------
# Prefix bound — the admissible lower bound beam search prunes with
# ---------------------------------------------------------------------------
#
# ``prefix_bound(probe, acg, plans, committed)`` bounds the full-schedule
# analytic cost of EVERY tiling that extends the partial assignment
# ``committed`` (loop var -> tile factor).  Committed loops cost exactly
# what the model would charge them; uncommitted loops are relaxed to their
# best case (min over their divisor grid, jointly within each group of
# loops that share a footprint dimension).  Admissibility — the bound is
# never greater than ``cost()`` of any completion — is what makes beam
# pruning safe, and is property-tested against the mnemonic-faithful model
# (tests/test_cost_model.py).  Relaxations used (each only ever *lowers*
# the bound):
#
# * transfers are charged at perfect edge coalescing (total bits moved /
#   edge bandwidth — every XFER mnemonic carries at most ``bandwidth``
#   bits, so the real chunk plan can only cost more, whatever the unroll
#   factor coalesces);
# * uncommitted loops outside an operand's reference contribute no reload
#   factor (their best case: untiled);
# * loop-iteration (ctrl) overhead is dropped entirely;
# * compute is charged at the mapped capability's full granularity
#   (``work / prod(geometry) * cycles`` — invocations can only be more).


def _dim_extent(ref: Ref, shape, d: int, extents: dict[str, int]) -> int:
    """Element extent of ``ref``'s dim ``d`` when each var in ``extents``
    ranges over [0, extent) — one dim of ``codelet.ref_footprint``."""
    span = 1
    for var, coeff in ref.idx[d].terms:
        if var in extents:
            span += abs(coeff) * (extents[var] - 1)
    base = ref.sizes[d] if ref.sizes else 1
    return min(shape[d], span - 1 + base)


def _var_components(ref: Ref) -> list[tuple[frozenset, tuple[int, ...]]]:
    """Group ``ref``'s loop vars into connected components of dims that
    share vars (conv windows couple ``oh`` and ``kh``); returns
    [(vars, dim indices)].  Dims with no loop vars are handled separately
    (their extent is constant)."""
    parent: dict[str, str] = {}

    def find(x):
        while parent[x] != x:
            parent[x] = parent[parent[x]]
            x = parent[x]
        return x

    dim_vars = [sorted(ref.idx[d].vars()) for d in range(len(ref.idx))]
    for vs in dim_vars:
        for v0 in vs:
            parent.setdefault(v0, v0)
        for a, b in zip(vs, vs[1:]):
            parent[find(a)] = find(b)
    comps: dict[str, tuple[set, list]] = {}
    for d, vs in enumerate(dim_vars):
        if not vs:
            continue
        root = find(vs[0])
        comp = comps.setdefault(root, (set(), []))
        comp[0].update(vs)
        comp[1].append(d)
    return [(frozenset(vs), tuple(ds)) for vs, ds in comps.values()]


_JOINT_CAP = 4096  # max joint grid combos per component before relaxing


def _operand_traffic_lb(cdlt: Codelet, p, committed: dict[str, int],
                        order: list[str], ranges: dict[str, int],
                        divisors: dict[str, list[int]]
                        ) -> tuple[float, float, float]:
    """Per-hop lower bounds for operand ``p`` under any completion of
    ``committed``: ``(elements moved, tile loads, rows moved)``.

    * *elements* bounds the bandwidth-limited cycles (bits / bandwidth);
    * *loads* bounds the mnemonic count — every tile load is at least one
      XFER, however well it coalesces (Fig 8b's reload tax: the term that
      makes the bound commitment-sensitive);
    * *rows* bounds the chunk count — one XFER carries at most
      ``coalesce`` contiguous rows (§4 Loop Unrolling).
    """
    s = cdlt.surrogates[p.surrogate]
    ref = p.ref
    if not ref.idx:                      # whole-surrogate reference
        elems = float(math.prod(s.shape))
        return elems, 1.0, elems / max(s.shape[-1], 1)
    ref_vars = set()
    for ix in ref.idx:
        ref_vars |= ix.vars()
    ref_vars &= set(ranges)
    last_dim = len(ref.idx) - 1

    def trips(var: str, factor: int) -> int:
        return math.ceil(ranges[var] / factor) if factor < ranges[var] else 1

    # reload factor of committed tiled NON-ref loops that provably sit
    # outside the transfer's insertion level: they precede (in nest order)
    # a committed tiled loop the reference DOES depend on
    tiled = {v for v, f in committed.items()
             if v in ranges and f < ranges[v]}
    ref_tiled_pos = [order.index(v) for v in ref_vars & tiled]
    outer = 1.0
    if ref_tiled_pos:
        level = max(ref_tiled_pos)
        for v0 in tiled - ref_vars:
            if order.index(v0) < level:
                outer *= trips(v0, committed[v0])

    elems = loads = rows = outer
    seen_dims: set[int] = set()
    for comp_vars, comp_dims in _var_components(ref):
        seen_dims.update(comp_dims)
        unc = sorted(v for v in comp_vars if v not in committed
                     and v in ranges)
        fixed = {v: committed[v] for v in comp_vars
                 if v in committed and v in ranges}
        # committed tiled loops of this component reload exactly
        loads *= math.prod(trips(v, f) for v, f in fixed.items()
                           if f < ranges[v])
        grids = [divisors.get(v, [ranges[v]]) for v in unc]
        if math.prod(len(g) for g in grids) > _JOINT_CAP:
            # relaxation: minimal per-dim extents, no reload factor
            ones = {v: 1 for v in comp_vars}
            elems *= math.prod(
                _dim_extent(ref, s.shape, d, ones) for d in comp_dims)
            rows *= math.prod(
                _dim_extent(ref, s.shape, d, ones)
                for d in comp_dims if d != last_dim)
            continue
        best_e, best_r = math.inf, math.inf
        for combo in itertools.product(*grids):
            ext = dict(fixed)
            ext.update(zip(unc, combo))
            n_loads = math.prod(trips(v, f) for v, f in ext.items())
            fp = [(_dim_extent(ref, s.shape, d, ext), d)
                  for d in comp_dims]
            full = math.prod(e for e, _ in fp)
            best_e = min(best_e, n_loads * full)
            best_r = min(best_r, n_loads * math.prod(
                e for e, d in fp if d != last_dim))
        elems *= best_e
        rows *= best_r
    for d in range(len(ref.idx)):        # constant dims
        if d not in seen_dims:
            e = _dim_extent(ref, s.shape, d, {})
            elems *= e
            if d != last_dim:
                rows *= e
    return elems, loads, rows


def _loop_ranges(cdlt: Codelet) -> dict[str, int]:
    return {l.var: l.trips for l in cdlt.loops()}


def _compute_lower_bound(cdlt: Codelet, acg: ACG) -> tuple[float, str]:
    """(cycles, slot class) of the mapped capability at full granularity —
    tiling-independent, since mapping happens before tiling."""
    (loops, op), = cdlt.computes()
    work = float(math.prod(l.trips for l in cdlt.loops()))
    cap = op.cap_obj
    if cap is None:
        return 0.0, "exec"
    per_inv = math.prod(cap.geometry) if cap.geometry else cap.out_elems
    return work / max(per_inv, 1) * cap.cycles, _compute_slot(op, acg)


def _hop_traffic(cdlt: Codelet, acg: ACG, plans, committed: dict[str, int],
                 divisors: dict[str, list[int]],
                 max_coalesce: int = 8) -> list[tuple[float, object]]:
    """[(cycles lower bound, plan)] per operand, summed over its hops.

    Each hop's XFER-mnemonic count is bounded below by the max of three
    floors — bandwidth (bits moved / edge bandwidth), loads (one mnemonic
    per tile load) and rows (at most ``max_coalesce`` contiguous rows per
    mnemonic) — each admissible for any tiling completion and any unroll
    factor up to ``max_coalesce``."""
    order = [l.var for l in cdlt.loops()]
    ranges = _loop_ranges(cdlt)
    out = []
    for p in plans:
        s = cdlt.surrogates[p.surrogate]
        elems, loads, rows = _operand_traffic_lb(cdlt, p, committed, order,
                                                 ranges, divisors)
        bits = elems * s.dtype.bits
        cyc = sum(max(bits / e.bandwidth, loads,
                      rows / max(max_coalesce, 1)) * e.latency
                  for e, _ in p.hops(acg))
        out.append((cyc, p))
    return out


def prefix_bounds(cdlt: Codelet, acg: ACG, plans, committed: dict[str, int],
                  *, divisors: dict[str, list[int]] | None = None,
                  max_coalesce: int = 8) -> tuple[float, float]:
    """``(packed form, serial form)`` of the prefix bound from ONE traffic
    analysis — the two differ only in how the same compute/transfer lower
    bounds combine, and beam ranking needs both per prefix."""
    if divisors is None:
        from .scheduler import _divisors
        divisors = {l.var: _divisors(l.trips) for l in cdlt.loops()}
    compute_lb, slot = _compute_lower_bound(cdlt, acg)
    transfer_lb = sum(c for c, _ in
                      _hop_traffic(cdlt, acg, plans, committed, divisors,
                                   max_coalesce=max_coalesce))
    serial = compute_lb + transfer_lb
    if acg.issue_slots > 1:
        # packed streams overlap classes: bound by the slowest slot class
        # at its per-packet capacity (the modulo-scheduling II argument)
        packed = max(compute_lb / DEFAULT_SLOT_CAPACITY.get(slot, 1),
                     transfer_lb / DEFAULT_SLOT_CAPACITY.get("mem", 1))
    else:
        packed = serial  # single-issue targets execute serially either way
    return packed, serial


def prefix_bound(cdlt: Codelet, acg: ACG, plans, committed: dict[str, int],
                 *, divisors: dict[str, list[int]] | None = None,
                 pack: bool = True, max_coalesce: int = 8) -> float:
    """Admissible lower bound on ``cost(...).cycles`` of every schedule
    extending the partial tiling ``committed`` (see module comment above).

    ``cdlt`` is the pre-tiling probe (``ScheduleSpace.probe``); ``plans``
    its operand plans; ``divisors`` the per-loop factor grids uncommitted
    loops may choose from (defaults to each loop's full divisor grid);
    ``max_coalesce`` must be at least the largest unroll factor a
    completion may use (rows coalesce up to it).  ``pack=False`` gives
    the tighter serial-sum form, valid only against
    ``cost(..., pack=False)``."""
    packed, serial = prefix_bounds(cdlt, acg, plans, committed,
                                   divisors=divisors,
                                   max_coalesce=max_coalesce)
    return packed if pack else serial


def transfer_hot_vars(cdlt: Codelet, acg: ACG, plans,
                      tiling: dict[str, int],
                      divisors: dict[str, list[int]] | None = None
                      ) -> list[str]:
    """Loop vars of the operand whose staging edges dominate transfer
    cycles under ``tiling`` — the loops transfer-aware mutation biases
    toward.  Deterministic (sorted) for seed-stable search."""
    if divisors is None:
        divisors = {}
    ranked = sorted(_hop_traffic(cdlt, acg, plans, tiling, divisors),
                    key=lambda cp: -cp[0])
    for cyc, p in ranked:
        if cyc <= 0:
            break
        vs = set()
        for ix in p.ref.idx:
            vs |= ix.vars()
        hot = sorted(vs & set(tiling))
        if hot:
            return hot
    return []


__all__ = ["CostReport", "cost", "prefix_bound", "prefix_bounds",
           "transfer_cost", "transfer_hot_vars"]
