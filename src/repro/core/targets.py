"""Bundled accelerator targets as declarative covenant specs, plus the
string-addressable target registry.

Every target here is *data* — an ``spec.ACGSpec`` listing memories,
capabilities, edges and mnemonic layouts (Table 3 attributes for the two
evaluation targets, the Figure-2 example, and our TPU-v5e adaptation) —
materialized through ``ACG.from_spec``.  Nothing in this module teaches
the compiler anything: adding an accelerator is ``repro.targets.register
(acg_spec(...))``, never a compiler edit (the BYOC seam, arXiv 2105.03215).

The registry resolves *names*, including derived-variant names:

    get_target("dnnweaver")                      # bundled spec
    get_target("dnnweaver@pe=32x32")             # spec.derive() on the fly
    get_target("hvx@issue_slots=8,VRF.depth=64") # multiple overrides

Mnemonic vocabularies follow §2.1.4: each target declares opcode + field
layouts; the *semantics* live in the simulator, never in the compiler.
"""
from __future__ import annotations

from .acg import ACG
from .spec import (ACGSpec, BINARY, UNARY, acg_spec, parse_overrides, scap,
                   scu, sedge, smem, sop)

# ---------------------------------------------------------------------------
# bundled specs
# ---------------------------------------------------------------------------

# Figure-2 running example: DRAM <-> Global Scratchpad (data_width=32,
# banks=7, depth=1024 => 28,672 B) feeding Scalar / 2-wide Vector / 2x2
# Matrix units.
EXAMPLE_SPEC = acg_spec(
    "example",
    memories=[
        smem("DRAM", data_width=32, banks=1, depth=1 << 28, offchip=True),
        smem("GSP", data_width=32, banks=7, depth=1024),
    ],
    computes=[
        scu("SCALAR", [
            *(scap(n, sop("i16", 1), [sop("i16", 1)]) for n in UNARY),
            *(scap(n, sop("i16", 1), [sop("i16", 1)] * 2) for n in BINARY),
            scap("MAC", sop("i32", 1),
                 [sop("i16", 1), sop("i16", 1), sop("i32", 1)],
                 geometry=(1, 1, 1)),
        ], slot="scalar"),
        scu("VECTOR", [
            *(scap(n, sop("i16", 2), [sop("i16", 2)]) for n in UNARY),
            *(scap(n, sop("i16", 2), [sop("i16", 2)] * 2) for n in BINARY),
        ], slot="vector"),
        scu("MATRIX", [
            scap("MMUL", sop("i16", 2, 2), [sop("i16", 2, 2), sop("i16", 2, 2)],
                 geometry=(2, 2, 2)),
            scap("GEMM", sop("i32", 2, 2),
                 [sop("i16", 2, 2), sop("i16", 2, 2), sop("i32", 2, 2)],
                 geometry=(2, 2, 2)),
            scap("MAC", sop("i32", 2, 2),
                 [sop("i16", 2, 2), sop("i16", 2, 2), sop("i32", 2, 2)],
                 geometry=(2, 2, 2)),
        ], slot="matrix"),
    ],
    edges=[
        sedge("DRAM", "GSP", bandwidth=224, bidir=True),  # Mem. Interface
        *(sedge("GSP", u, bandwidth=224, bidir=True)
          for u in ("SCALAR", "VECTOR", "MATRIX")),
    ],
    addr_bits=24,
)


# DNNWeaver (Table 3): 64x64 systolic array + 64-lane SIMD, per-operand
# buffers (IBUF/WBUF/OBUF/BBUF/VMEM1/VMEM2), hardware loop sequencer.
DNNWEAVER_SPEC = acg_spec(
    "dnnweaver",
    memories=[
        smem("DRAM", data_width=8, banks=1, depth=32_000_000_000,
             offchip=True),
        smem("IBUF", data_width=8, banks=64, depth=2048),
        smem("WBUF", data_width=8, banks=4096, depth=4096),
        smem("OBUF", data_width=32, banks=64, depth=2048),
        smem("BBUF", data_width=32, banks=64, depth=1024),
        smem("VMEM1", data_width=32, banks=64, depth=2048),
        smem("VMEM2", data_width=32, banks=64, depth=2048),
    ],
    computes=[
        scu("SYSTOLIC", [
            # one invocation: 64-wide input row x 64x64 weights -> 64 psums
            scap("GEMM", sop("i32", 64),
                 [sop("i8", 64), sop("i8", 64, 64), sop("i32", 64)],
                 geometry=(1, 64, 64)),
            scap("MAC", sop("i32", 64),
                 [sop("i8", 64), sop("i8", 64, 64), sop("i32", 64)],
                 geometry=(1, 64, 64)),
            scap("MVMUL", sop("i32", 64), [sop("i8", 64), sop("i8", 64, 64)],
                 geometry=(1, 64, 64)),
        ], slot="systolic"),
        scu("SIMD", [
            *(scap(n, sop("i32", 64), [sop("i32", 64)] * 2) for n in BINARY),
            *(scap(n, sop("i32", 64), [sop("i32", 64)]) for n in UNARY),
            scap("MAC", sop("i32", 64),
                 [sop("i32", 64), sop("i32", 64), sop("i32", 64)],
                 geometry=(1, 64, 1)),
        ], slot="simd"),
    ],
    edges=[
        # off-chip interface: 256-bit AXI per transfer op
        *(sedge("DRAM", buf, bandwidth=256)
          for buf in ("IBUF", "WBUF", "BBUF")),
        sedge("OBUF", "DRAM", bandwidth=256),
        sedge("DRAM", "VMEM1", bandwidth=256, bidir=True),
        sedge("DRAM", "VMEM2", bandwidth=256, bidir=True),
        # on-chip: buffers feed the systolic array (unidirectional, §5.1.1)
        sedge("IBUF", "SYSTOLIC", bandwidth=8 * 64),
        sedge("WBUF", "SYSTOLIC", bandwidth=8 * 4096),
        sedge("BBUF", "SYSTOLIC", bandwidth=32 * 64),
        sedge("SYSTOLIC", "OBUF", bandwidth=32 * 64),
        sedge("OBUF", "SIMD", bandwidth=32 * 64),  # SIMD consumes OBUF
        sedge("VMEM1", "SIMD", bandwidth=32 * 64, bidir=True),
        sedge("VMEM2", "SIMD", bandwidth=32 * 64, bidir=True),
    ],
    # dedicated per-operand staging buffers of the systolic array
    operand_ports={("SYSTOLIC", c): ("IBUF", "WBUF", "OBUF", "OBUF")
                   for c in ("GEMM", "MAC", "MVMUL")},
    loop_overhead=0,  # hardware loop sequencer (FSM-driven walkers)
    addr_bits=32,
)


# Qualcomm HVX (Table 3): scalar CORE (GRF) and 32-lane x 128B vector unit
# (VRF), both fed from L2.  L2 is the operand home: DRAM<->L2 is
# hardware-managed (paper: DRAM absent from the ACG), so L2 carries
# offchip=True = "operands live here".  4-wide VLIW issue.
HVX_SPEC = acg_spec(
    "hvx",
    memories=[
        smem("L2", data_width=8, banks=32, depth=1024 * 4, offchip=True),
        smem("GRF", data_width=32, banks=4, depth=32),
        smem("VRF", data_width=1024, banks=32, depth=32),
    ],
    computes=[
        scu("CORE", [
            scap("ADD", sop("u8", 8), [sop("u8", 8)] * 2),
            scap("ADD", sop("i32", 1), [sop("i32", 1)] * 2),
            scap("SUB", sop("i32", 1), [sop("i32", 1)] * 2),
            scap("MUL", sop("i32", 1), [sop("i32", 1)] * 2),
            scap("MAX", sop("i32", 1), [sop("i32", 1)] * 2),
            scap("MIN", sop("i32", 1), [sop("i32", 1)] * 2),
            scap("MAC", sop("i32", 1),
                 [sop("u8", 4), sop("u8", 4), sop("i32", 1)],
                 geometry=(1, 1, 4)),
            *(scap(n, sop("i32", 1), [sop("i32", 1)]) for n in UNARY),
        ], slot="scalar"),
        scu("HVX", [
            *(scap(n, sop("i32", 32), [sop("i32", 32)] * 2) for n in BINARY),
            *(scap(n, sop("i32", 32), [sop("i32", 32)]) for n in UNARY),
            scap("MVMUL", sop("i32", 32), [sop("u8", 32, 4), sop("u8", 4)],
                 geometry=(1, 32, 4)),
            scap("GEMM", sop("i32", 32),
                 [sop("u8", 32, 4), sop("u8", 4), sop("i32", 32)],
                 geometry=(1, 32, 4)),
            scap("GEMM", sop("u32", 32),
                 [sop("u8", 32, 4), sop("u8", 4), sop("u32", 32)],
                 geometry=(1, 32, 4)),
            scap("MAC", sop("i32", 32),
                 [sop("u8", 32, 4), sop("u8", 4), sop("i32", 32)],
                 geometry=(1, 32, 4)),
        ], slot="vector"),
    ],
    edges=[
        sedge("L2", "GRF", bandwidth=32 * 4, bidir=True),
        sedge("L2", "VRF", bandwidth=1024, bidir=True),
        sedge("GRF", "CORE", bandwidth=32 * 4, bidir=True),
        sedge("VRF", "HVX", bandwidth=1024 * 2, bidir=True),
    ],
    issue_slots=4,
    addr_bits=20,
)


# TPU v5e (our adaptation target, DESIGN.md §3).  Hardware constants reused
# by the roofline model (per chip).
TPU_V5E = dict(
    peak_bf16_flops=197e12,   # FLOP/s
    hbm_bw=819e9,             # B/s
    ici_bw_per_link=50e9,     # B/s per link (bidirectional counted once)
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
    clock_hz=940e6,
)

# * HBM -> VMEM edge bandwidth: 819 GB/s / 940 MHz ~= 871 B/cycle => 7168
#   bits per 'transfer op' (128 lanes * 56 bits; bandwidth only drives
#   cost, not correctness).
# * VMEM: (8,128) f32 native tile = 4096 B addressable element.
# * MXU: 128x128 systolic bf16 GEMM; VPU: 8x128 f32 vector ALU.
TPU_V5E_SPEC = acg_spec(
    "tpu_v5e",
    memories=[
        smem("HBM", data_width=256, banks=32,
             depth=(16 * 2**30 * 8) // (256 * 32), offchip=True),
        # elem = 32 bits * 1024 banks = 4096 B = one (8,128) f32 tile
        smem("VMEM", data_width=32, banks=1024,
             depth=(128 * 2**20) // 4096),
        smem("SMEM", data_width=32, banks=1, depth=4096),
    ],
    computes=[
        scu("MXU", [
            scap("GEMM", sop("f32", 128, 128),
                 [sop("bf16", 128, 128), sop("bf16", 128, 128),
                  sop("f32", 128, 128)],
                 geometry=(128, 128, 128)),
            scap("MAC", sop("f32", 128, 128),
                 [sop("bf16", 128, 128), sop("bf16", 128, 128),
                  sop("f32", 128, 128)],
                 geometry=(128, 128, 128)),
            scap("MMUL", sop("f32", 128, 128),
                 [sop("bf16", 128, 128), sop("bf16", 128, 128)],
                 geometry=(128, 128, 128)),
            scap("GEMM", sop("i32", 128, 128),
                 [sop("i8", 128, 128), sop("i8", 128, 128),
                  sop("i32", 128, 128)],
                 geometry=(128, 128, 128)),
        ], slot="mxu"),
        scu("VPU", [
            *(scap(n, sop("f32", 8, 128), [sop("f32", 8, 128)] * 2)
              for n in BINARY),
            *(scap(n, sop("f32", 8, 128), [sop("f32", 8, 128)])
              for n in UNARY),
            scap("MAC", sop("f32", 8, 128), [sop("f32", 8, 128)] * 3,
                 geometry=(8, 128, 1)),
            *(scap(n, sop("i32", 8, 128), [sop("i32", 8, 128)] * 2)
              for n in BINARY),
        ], slot="vpu"),
    ],
    edges=[
        sedge("HBM", "VMEM", bandwidth=7168, bidir=True),
        sedge("VMEM", "MXU", bandwidth=32 * 1024, bidir=True),
        sedge("VMEM", "VPU", bandwidth=32 * 1024, bidir=True),
        sedge("SMEM", "VPU", bandwidth=32, bidir=True),
    ],
    addr_bits=32,
)


BUNDLED_SPECS: dict[str, ACGSpec] = {
    s.name: s for s in (EXAMPLE_SPEC, DNNWEAVER_SPEC, HVX_SPEC, TPU_V5E_SPEC)
}


# ---------------------------------------------------------------------------
# the registry: string names (incl. derived variants) -> ACGs
# ---------------------------------------------------------------------------

# name -> zero-arg ACG factory.  Spec-registered entries carry the spec on
# the factory (``factory.spec``) so variants derive from data, not from a
# graph snapshot; plain factories (``driver.register_target``) still work
# and are snapshotted on demand.
TARGETS: dict[str, object] = {}


def _spec_factory(spec: ACGSpec):
    def factory() -> ACG:
        return ACG.from_spec(spec)

    factory.spec = spec
    factory.__name__ = f"{spec.name}_from_spec"
    return factory


def register_spec(spec: ACGSpec, name: str | None = None,
                  validate: bool = True) -> ACGSpec:
    """Register a declarative target.  ``repro.compile(layer, name)`` (and
    every other driver entry point) resolves it — including ``name@k=v``
    derived variants — from then on.  Registering under an alias renames
    the spec, so canonical derived-variant names stay resolvable."""
    import dataclasses

    from .spec import validate_spec

    if name is not None and name != spec.name:
        spec = dataclasses.replace(spec, name=name)
    if validate:
        validate_spec(spec)
    TARGETS[spec.name] = _spec_factory(spec)
    return spec


for _spec in BUNDLED_SPECS.values():
    register_spec(_spec, validate=False)


def list_targets() -> list[str]:
    return sorted(TARGETS)


def _lookup(name: str):
    """-> (factory, registered_spec_or_None, overrides_suffix).  THE name
    resolution rule: an exact registered name wins — including names that
    themselves contain ``@`` (e.g. a registered derived spec) — before
    falling back to the ``base@overrides`` variant grammar."""
    factory = TARGETS.get(name)
    if factory is not None:
        return factory, getattr(factory, "spec", None), ""
    base, _, overrides = name.partition("@")
    factory = TARGETS.get(base)
    if factory is None:
        raise KeyError(
            f"unknown target {base!r}; known: {list_targets()}")
    return factory, getattr(factory, "spec", None), overrides


def resolve_factory(name: str):
    """The registered factory a target name resolves against, or None —
    a thin view over ``_lookup`` so the driver's memo-invalidation
    identity and actual resolution can never diverge."""
    try:
        return _lookup(name)[0]
    except KeyError:
        return None


def get_spec(name: str) -> ACGSpec:
    """The covenant spec behind a target name.  Variant names
    (``base@k=v``) return the derived spec; factory-registered targets are
    snapshotted via ``acg.to_spec()``."""
    factory, spec, overrides = _lookup(name)
    if spec is None:
        spec = factory().to_spec()
    if overrides:
        spec = spec.derive(**parse_overrides(overrides))
    return spec


def get_target(name: str) -> ACG:
    """Resolve a target name to a fresh ACG.  ``base@key=value,...`` names
    derive a variant from the base spec on the fly; BYOC pass hooks
    installed by the base factory carry over to variants."""
    factory, spec, overrides = _lookup(name)
    if not overrides:
        return factory()
    hooks_donor = None
    if spec is None:
        hooks_donor = factory()
        spec = hooks_donor.to_spec()
    acg = ACG.from_spec(spec.derive(**parse_overrides(overrides)))
    if hooks_donor is not None:
        acg.pass_overrides.update(hooks_donor.pass_overrides)
        acg.extra_passes.extend(hooks_donor.extra_passes)
    return acg


# ---------------------------------------------------------------------------
# thin back-compat constructors
# ---------------------------------------------------------------------------


def example_acg() -> ACG:
    return ACG.from_spec(EXAMPLE_SPEC)


def dnnweaver_acg() -> ACG:
    return ACG.from_spec(DNNWEAVER_SPEC)


def hvx_acg() -> ACG:
    return ACG.from_spec(HVX_SPEC)


def tpu_v5e_acg() -> ACG:
    return ACG.from_spec(TPU_V5E_SPEC)


__all__ = [
    "BINARY", "BUNDLED_SPECS", "DNNWEAVER_SPEC", "EXAMPLE_SPEC", "HVX_SPEC",
    "TARGETS", "TPU_V5E", "TPU_V5E_SPEC", "UNARY", "dnnweaver_acg",
    "example_acg", "get_spec", "get_target", "hvx_acg", "list_targets",
    "register_spec", "resolve_factory", "tpu_v5e_acg",
]
