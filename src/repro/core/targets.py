"""Predefined ACGs: the paper's Figure-2 example, the two evaluation targets
(DNNWeaver and Qualcomm HVX, attributes from Table 3), and our TPU-v5e
adaptation target.

Mnemonic vocabularies follow §2.1.4: each target declares opcode + field
layouts; the *semantics* live in the simulator (like the vendor cycle-accurate
simulators the paper measures with), never in the compiler.
"""
from __future__ import annotations

from .acg import ACG, cap, efield, ifield, ospec

# Elementwise capability names shared across targets (Table 1).
UNARY = ("RELU", "SIGMOID", "TANH")
BINARY = ("ADD", "SUB", "MUL", "DIV", "MAX", "MIN")


def _define_common_mnemonics(acg: ACG, addr_bits: int = 24) -> None:
    """Target-independent mnemonic shapes; per-target fields differ only in
    widths/enums, demonstrating the paper's 'semantics-free' reuse claim."""
    mems = [m.name for m in acg.memory_nodes()]
    units = [c.name for c in acg.compute_nodes()]
    acg.define_mnemonic(
        "XFER", 0x01,
        [
            efield("SRC_NODE", 4, mems, rw="r"),
            efield("DST_NODE", 4, mems, rw="w"),
            ifield("SRC_ADDR", addr_bits, rw="r"),
            ifield("DST_ADDR", addr_bits, rw="w"),
            # 2-D DMA burst descriptor: ROWS rows of ROW_BYTES each, with
            # per-side row strides in bytes (strided bursts, like real DMA
            # engines; one XFER = one transfer operation on the edge).
            ifield("ROWS", 16),
            ifield("ROW_BYTES", 24),
            ifield("SRC_STRIDE", 24),
            ifield("DST_STRIDE", 24),
        ],
    )
    acg.define_mnemonic(
        "ALLOC", 0x02,
        [efield("NODE", 4, mems, rw="w"), ifield("ADDR", addr_bits, rw="w"),
         ifield("SIZE", 24)],
    )
    # per-iteration loop bookkeeping (branch/index update); hardware-loop
    # targets set loop_overhead=0 and the generator skips it entirely.
    acg.define_mnemonic("LOOPI", 0x03, [ifield("LEVEL", 8), ifield("TRIP", 24)])
    for i, name in enumerate(UNARY):
        acg.define_mnemonic(
            name, 0x10 + i,
            [ifield("SRC_ADDR", addr_bits, rw="r"), ifield("DST_ADDR", addr_bits, rw="w"),
             ifield("N", 16), efield("TGT", 3, units)],
        )
    for i, name in enumerate(BINARY):
        acg.define_mnemonic(
            name, 0x20 + i,
            [ifield("SRC1_ADDR", addr_bits, rw="r"), ifield("SRC2_ADDR", addr_bits, rw="r"),
             ifield("DST_ADDR", addr_bits, rw="w"), ifield("N", 16), efield("TGT", 3, units)],
        )
    for i, name in enumerate(("MAC", "GEMM", "MMUL", "MVMUL")):
        acg.define_mnemonic(
            name, 0x30 + i,
            [ifield("SRC1_ADDR", addr_bits, rw="r"), ifield("SRC2_ADDR", addr_bits, rw="r"),
             ifield("ACC_ADDR", addr_bits, rw="r"), ifield("DST_ADDR", addr_bits, rw="w"),
             ifield("M", 16), ifield("N", 16), ifield("K", 16),
             # row strides in *elements* for the 2-D operand views
             ifield("LD1", 16), ifield("LD2", 16), ifield("LDD", 16),
             efield("TGT", 3, units)],
        )


# ---------------------------------------------------------------------------
# Figure-2 running example
# ---------------------------------------------------------------------------


def example_acg() -> ACG:
    """The generic accelerator of Figure 2/3/5: DRAM <-> Global Scratchpad
    (data_width=32, banks=7, depth=1024 => 28,672 B) feeding Scalar / 2-wide
    Vector / 2x2 Matrix units."""
    g = ACG("example")
    g.add_memory("DRAM", data_width=32, banks=1, depth=1 << 28, offchip=True)
    g.add_memory("GSP", data_width=32, banks=7, depth=1024)
    g.add_compute("SCALAR", [
        *(cap(n, ospec("i16", 1), [ospec("i16", 1)]) for n in UNARY),
        *(cap(n, ospec("i16", 1), [ospec("i16", 1)] * 2) for n in BINARY),
        cap("MAC", ospec("i32", 1), [ospec("i16", 1), ospec("i16", 1), ospec("i32", 1)],
            geometry=(1, 1, 1)),
    ], slot="scalar")
    g.add_compute("VECTOR", [
        *(cap(n, ospec("i16", 2), [ospec("i16", 2)]) for n in UNARY),
        *(cap(n, ospec("i16", 2), [ospec("i16", 2)] * 2) for n in BINARY),
    ], slot="vector")
    g.add_compute("MATRIX", [
        cap("MMUL", ospec("i16", 2, 2), [ospec("i16", 2, 2), ospec("i16", 2, 2)],
            geometry=(2, 2, 2)),
        cap("GEMM", ospec("i32", 2, 2),
            [ospec("i16", 2, 2), ospec("i16", 2, 2), ospec("i32", 2, 2)],
            geometry=(2, 2, 2)),
        cap("MAC", ospec("i32", 2, 2),
            [ospec("i16", 2, 2), ospec("i16", 2, 2), ospec("i32", 2, 2)],
            geometry=(2, 2, 2)),
    ], slot="matrix")
    g.connect("DRAM", "GSP", bandwidth=224, bidir=True)  # Mem. Interface
    for u in ("SCALAR", "VECTOR", "MATRIX"):
        g.connect("GSP", u, bandwidth=224, bidir=True)
    _define_common_mnemonics(g)
    return g


# ---------------------------------------------------------------------------
# DNNWeaver (Table 3)
# ---------------------------------------------------------------------------


def dnnweaver_acg() -> ACG:
    """DNNWeaver: 64x64 systolic array + 64-lane SIMD, per-operand buffers.

    Attributes follow Table 3 verbatim: IBUF/WBUF/OBUF/BBUF/VMEM1/VMEM2 widths
    + the systolic GEMM capability (i32,64)=GEMM((i8,64),(i8,64,64),(i32,64)).
    """
    g = ACG("dnnweaver")
    g.add_memory("DRAM", data_width=8, banks=1, depth=32_000_000_000, offchip=True)
    g.add_memory("IBUF", data_width=8, banks=64, depth=2048)
    g.add_memory("WBUF", data_width=8, banks=4096, depth=4096)
    g.add_memory("OBUF", data_width=32, banks=64, depth=2048)
    g.add_memory("BBUF", data_width=32, banks=64, depth=1024)
    g.add_memory("VMEM1", data_width=32, banks=64, depth=2048)
    g.add_memory("VMEM2", data_width=32, banks=64, depth=2048)
    g.add_compute("SYSTOLIC", [
        # one invocation: 64-wide input row x 64x64 weights -> 64 int32 psums
        cap("GEMM", ospec("i32", 64), [ospec("i8", 64), ospec("i8", 64, 64), ospec("i32", 64)],
            geometry=(1, 64, 64)),
        cap("MAC", ospec("i32", 64), [ospec("i8", 64), ospec("i8", 64, 64), ospec("i32", 64)],
            geometry=(1, 64, 64)),
        cap("MVMUL", ospec("i32", 64), [ospec("i8", 64), ospec("i8", 64, 64)],
            geometry=(1, 64, 64)),
    ], slot="systolic")
    g.add_compute("SIMD", [
        *(cap(n, ospec("i32", 64), [ospec("i32", 64)] * 2) for n in BINARY),
        *(cap(n, ospec("i32", 64), [ospec("i32", 64)]) for n in UNARY),
        cap("MAC", ospec("i32", 64), [ospec("i32", 64), ospec("i32", 64), ospec("i32", 64)],
            geometry=(1, 64, 1)),
    ], slot="simd")
    # off-chip interface: 256-bit AXI per transfer op
    for buf in ("IBUF", "WBUF", "BBUF"):
        g.connect("DRAM", buf, bandwidth=256)
    g.connect("OBUF", "DRAM", bandwidth=256)
    g.connect("DRAM", "VMEM1", bandwidth=256, bidir=True)
    g.connect("DRAM", "VMEM2", bandwidth=256, bidir=True)
    # on-chip: buffers feed the systolic array (unidirectional, §5.1.1)
    g.connect("IBUF", "SYSTOLIC", bandwidth=8 * 64)
    g.connect("WBUF", "SYSTOLIC", bandwidth=8 * 4096)
    g.connect("BBUF", "SYSTOLIC", bandwidth=32 * 64)
    g.connect("SYSTOLIC", "OBUF", bandwidth=32 * 64)
    g.connect("OBUF", "SIMD", bandwidth=32 * 64)  # SIMD consumes OBUF
    g.connect("VMEM1", "SIMD", bandwidth=32 * 64, bidir=True)
    g.connect("VMEM2", "SIMD", bandwidth=32 * 64, bidir=True)
    # dedicated per-operand staging buffers of the systolic array
    for c in ("GEMM", "MAC", "MVMUL"):
        g.operand_ports[("SYSTOLIC", c)] = ("IBUF", "WBUF", "OBUF", "OBUF")
    g.loop_overhead = 0  # hardware loop sequencer (FSM-driven walkers)
    _define_common_mnemonics(g, addr_bits=32)
    return g


# ---------------------------------------------------------------------------
# Qualcomm HVX (Table 3)
# ---------------------------------------------------------------------------


def hvx_acg() -> ACG:
    """Hexagon + HVX: scalar CORE (GRF) and 32-lane x 128B vector unit (VRF),
    both fed from L2 (DRAM is hardware-managed, hence absent — §5.1.1).
    4-wide VLIW issue (mnemonic packing target)."""
    g = ACG("hvx", issue_slots=4)
    # L2 is the operand home: DRAM<->L2 is hardware-managed (paper: DRAM absent
    # from the ACG), so L2 carries offchip=True = "operands live here" and its
    # capacity is not a staging constraint.
    g.add_memory("L2", data_width=8, banks=32, depth=1024 * 4, offchip=True)
    g.add_memory("GRF", data_width=32, banks=4, depth=32)
    g.add_memory("VRF", data_width=1024, banks=32, depth=32)
    g.add_compute("CORE", [
        cap("ADD", ospec("u8", 8), [ospec("u8", 8)] * 2),
        cap("ADD", ospec("i32", 1), [ospec("i32", 1)] * 2),
        cap("SUB", ospec("i32", 1), [ospec("i32", 1)] * 2),
        cap("MUL", ospec("i32", 1), [ospec("i32", 1)] * 2),
        cap("MAX", ospec("i32", 1), [ospec("i32", 1)] * 2),
        cap("MIN", ospec("i32", 1), [ospec("i32", 1)] * 2),
        cap("MAC", ospec("i32", 1), [ospec("u8", 4), ospec("u8", 4), ospec("i32", 1)],
            geometry=(1, 1, 4)),
        *(cap(n, ospec("i32", 1), [ospec("i32", 1)]) for n in UNARY),
    ], slot="scalar")
    g.add_compute("HVX", [
        *(cap(n, ospec("i32", 32), [ospec("i32", 32)] * 2) for n in BINARY),
        *(cap(n, ospec("i32", 32), [ospec("i32", 32)]) for n in UNARY),
        cap("MVMUL", ospec("i32", 32), [ospec("u8", 32, 4), ospec("u8", 4)],
            geometry=(1, 32, 4)),
        cap("GEMM", ospec("i32", 32), [ospec("u8", 32, 4), ospec("u8", 4), ospec("i32", 32)],
            geometry=(1, 32, 4)),
        cap("GEMM", ospec("u32", 32), [ospec("u8", 32, 4), ospec("u8", 4), ospec("u32", 32)],
            geometry=(1, 32, 4)),
        cap("MAC", ospec("i32", 32), [ospec("u8", 32, 4), ospec("u8", 4), ospec("i32", 32)],
            geometry=(1, 32, 4)),
    ], slot="vector")
    g.connect("L2", "GRF", bandwidth=32 * 4, bidir=True)
    g.connect("L2", "VRF", bandwidth=1024, bidir=True)
    g.connect("GRF", "CORE", bandwidth=32 * 4, bidir=True)
    g.connect("VRF", "HVX", bandwidth=1024 * 2, bidir=True)
    _define_common_mnemonics(g, addr_bits=20)
    return g


# ---------------------------------------------------------------------------
# TPU v5e (our adaptation target, DESIGN.md §3)
# ---------------------------------------------------------------------------

# Hardware constants reused by the roofline model (per chip).
TPU_V5E = dict(
    peak_bf16_flops=197e12,   # FLOP/s
    hbm_bw=819e9,             # B/s
    ici_bw_per_link=50e9,     # B/s per link (bidirectional counted once)
    hbm_bytes=16 * 2**30,
    vmem_bytes=128 * 2**20,
    clock_hz=940e6,
)


def tpu_v5e_acg() -> ACG:
    """TPU v5e as an ACG.

    * HBM -> VMEM edge bandwidth: 819 GB/s / 940 MHz ~= 871 B/cycle => 6968
      bits per 'transfer op' (we round to 7168 = 128 lanes * 56 bits for
      modeling; bandwidth only drives cost, not correctness).
    * VMEM: (8,128) f32 native tile = 4096 B addressable element; depth such
      that capacity = 128 MiB.
    * MXU: 128x128 systolic bf16 GEMM; VPU: 8x128 f32 vector ALU.

    Algorithm-1 validation against this graph produces exactly the Pallas
    BlockSpec constraints: block byte-size multiple of the (8,128) element,
    all live blocks within VMEM capacity.
    """
    g = ACG("tpu_v5e")
    g.add_memory("HBM", data_width=256, banks=32, depth=(16 * 2**30 * 8) // (256 * 32),
                 offchip=True)
    # elem = 32 bits * 1024 banks = 4096 B = one (8,128) f32 tile
    g.add_memory("VMEM", data_width=32, banks=1024, depth=(128 * 2**20) // 4096)
    g.add_memory("SMEM", data_width=32, banks=1, depth=4096)
    g.add_compute("MXU", [
        cap("GEMM", ospec("f32", 128, 128),
            [ospec("bf16", 128, 128), ospec("bf16", 128, 128), ospec("f32", 128, 128)],
            geometry=(128, 128, 128)),
        cap("MAC", ospec("f32", 128, 128),
            [ospec("bf16", 128, 128), ospec("bf16", 128, 128), ospec("f32", 128, 128)],
            geometry=(128, 128, 128)),
        cap("MMUL", ospec("f32", 128, 128), [ospec("bf16", 128, 128), ospec("bf16", 128, 128)],
            geometry=(128, 128, 128)),
        cap("GEMM", ospec("i32", 128, 128),
            [ospec("i8", 128, 128), ospec("i8", 128, 128), ospec("i32", 128, 128)],
            geometry=(128, 128, 128)),
    ], slot="mxu")
    g.add_compute("VPU", [
        *(cap(n, ospec("f32", 8, 128), [ospec("f32", 8, 128)] * 2) for n in BINARY),
        *(cap(n, ospec("f32", 8, 128), [ospec("f32", 8, 128)]) for n in UNARY),
        cap("MAC", ospec("f32", 8, 128), [ospec("f32", 8, 128)] * 3, geometry=(8, 128, 1)),
        *(cap(n, ospec("i32", 8, 128), [ospec("i32", 8, 128)] * 2) for n in BINARY),
    ], slot="vpu")
    g.connect("HBM", "VMEM", bandwidth=7168, bidir=True)
    g.connect("VMEM", "MXU", bandwidth=32 * 1024, bidir=True)
    g.connect("VMEM", "VPU", bandwidth=32 * 1024, bidir=True)
    g.connect("SMEM", "VPU", bandwidth=32, bidir=True)
    _define_common_mnemonics(g, addr_bits=32)
    return g


TARGETS = {
    "example": example_acg,
    "dnnweaver": dnnweaver_acg,
    "hvx": hvx_acg,
    "tpu_v5e": tpu_v5e_acg,
}


def get_target(name: str) -> ACG:
    try:
        return TARGETS[name]()
    except KeyError as e:
        raise KeyError(f"unknown target {name!r}; known: {sorted(TARGETS)}") from e
