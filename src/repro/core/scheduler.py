"""The Covenant scheduling pipeline (§3.2 + Algorithm 1).

Stages, each a gradual Codelet transformation:

1. ``place_operands``  — inp/out surrogates move to the highest memory level
   (longest path to compute; off-chip when present).
2. ``map_compute``     — assign each compute op to an ACG compute node.  The
   paper's rule picks the node "capable of performing the most operations at
   a time"; with ``vectorize=False`` we pick the *least* parallel node, which
   is the unoptimized baseline that Fig-12's Vectorization pass improves on.
3. ``choose_tiling``   — Algorithm 1: enumerate loop-factor permutations,
   keep those whose staged tiles are data_width-aligned and fit every memory
   node on the transfer paths, then pick the cheapest by the cost model.
4. ``split_loops``     — canonical two-level nest: tile loops (outer,
   stride=tile) then intra loops; refs rewritten affinely.
5. ``insert_transfers``— per-operand staging along ACG shortest paths
   (respecting ``operand_ports``), allocation transfers create ``local``
   surrogates, write-backs return results to the operand home (Fig 8c).

All library codelets are perfect nests with a single compute op, which these
stages assume (asserted) — that covers the paper's full benchmark set.
"""
from __future__ import annotations

import dataclasses
import itertools
import math

from .acg import ACG, Capability, ComputeNode, MemoryNode
from .codelet import Aff, Codelet, Compute, Loop, Ref, Surrogate, Transfer, ref_footprint

# Capability aliasing: a codelet MAC can be served by any matmul-family
# capability (§2.1.3: capabilities need not map 1:1 onto mnemonics).
MATMUL_FAMILY = ("MAC", "GEMM", "MVMUL", "MMUL")


def capability_candidates(acg: ACG, op: Compute):
    """(node, capability) pairs able to execute ``op``, best granularity first."""
    names = MATMUL_FAMILY if op.capability in MATMUL_FAMILY else (op.capability,)
    cands = []
    for name in names:
        for node, c in acg.supporting_nodes(name, op.dtype):
            cands.append((node, c))
    # prefer higher out_elems, then deeper reduction granularity
    cands.sort(key=lambda nc: (-nc[1].out_elems,
                               -(nc[1].geometry[2] if nc[1].geometry else 1)))
    return cands


# ---------------------------------------------------------------------------
# Stage 1+2: placement and compute mapping
# ---------------------------------------------------------------------------


def place_operands(cdlt: Codelet, acg: ACG) -> None:
    home = acg.highest_memory().name
    for s in cdlt.surrogates.values():
        if s.kind in ("inp", "out") and s.loc is None:
            s.loc = home
    cdlt.note(f"place_operands: home={home}")


def map_compute(cdlt: Codelet, acg: ACG, vectorize: bool = True) -> None:
    for _, op in cdlt.computes():
        cands = capability_candidates(acg, op)
        if not cands:
            raise ValueError(
                f"no ACG node in {acg.name} supports capability {op.capability!r}"
                f" (dtype {op.dtype})")
        node, c = cands[0] if vectorize else cands[-1]
        op.loc, op.cap_obj = node.name, c
        cdlt.note(f"map_compute: {op.capability} -> {node.name} [{c}]"
                  f" ({'max' if vectorize else 'min'} granularity)")


# ---------------------------------------------------------------------------
# Transfer-path resolution
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class OperandPlan:
    """How one compute operand is staged: the memory path home -> staging."""

    surrogate: str
    is_output: bool
    path: list[str]          # memory nodes, home first, staging last
    ref: Ref                 # the compute op's reference (original index space)

    @property
    def staging(self) -> str:
        return self.path[-1]

    def hops(self, acg: ACG):
        """(edge, charge_node) per hop.  Inputs move home->staging along the
        listed order; outputs physically move staging->home, so the edge is
        the reverse one.  ``charge_node`` is the staging-side node whose
        capacity the tile occupies (Algorithm 1's ``storage[t.dst]``)."""
        out = []
        for a, b in zip(self.path, self.path[1:]):
            edge = acg.edge(b, a) if self.is_output else acg.edge(a, b)
            out.append((edge, b))
        return out


def plan_operands(cdlt: Codelet, acg: ACG) -> list[OperandPlan]:
    (loops, op), = cdlt.computes()
    ports = acg.operand_ports.get((op.loc, op.cap_obj.name))
    plans: list[OperandPlan] = []
    seen: set[str] = set()
    refs = list(op.ins) + [op.out]
    for i, r in enumerate(refs):
        s = cdlt.surrogates[r.var]
        is_out = s.kind == "out" and (i == len(refs) - 1 or r.var == op.out.var)
        if r.var in seen:
            continue
        seen.add(r.var)
        if ports is not None:
            staging = ports[min(i, len(ports) - 1)]
            if is_out:
                # physical flow staging -> home; list home-first
                path_nodes = acg.shortest_path(staging, s.loc)
                mem_path = [p for p in reversed(path_nodes)
                            if isinstance(acg.nodes[p], MemoryNode)]
            else:
                path_nodes = acg.shortest_path(s.loc, staging)
                mem_path = [p for p in path_nodes
                            if isinstance(acg.nodes[p], MemoryNode)]
        elif is_out:
            # stage where the compute node can write, walking back to home
            full = acg.shortest_path(op.loc, s.loc)
            mem_path = [p for p in full if isinstance(acg.nodes[p], MemoryNode)]
            mem_path = list(reversed(mem_path))  # home first, staging last
        else:
            # walk toward the compute node; staging = last memory before it
            full = acg.shortest_path(s.loc, op.loc)
            mem_path = [p for p in full[:-1] if isinstance(acg.nodes[p], MemoryNode)]
        assert mem_path and mem_path[0] == s.loc, (r.var, mem_path)
        plans.append(OperandPlan(r.var, is_out, mem_path, r))
    return plans


# ---------------------------------------------------------------------------
# Stage 3: Algorithm 1 — tiling validation + selection
# ---------------------------------------------------------------------------


def _divisors(n: int, cap: int = 8) -> list[int]:
    ds = [d for d in range(1, n + 1) if n % d == 0]
    if len(ds) <= cap:
        return ds
    # keep a spread: smallest, largest, and geometrically spaced middles
    keep = {ds[0], ds[-1]}
    want = cap - len(keep)
    for i in range(1, want + 1):
        keep.add(ds[round(i * (len(ds) - 1) / (want + 1))])
    return sorted(keep)


def _tile_footprints(cdlt: Codelet, plans: list[OperandPlan],
                     tiling: dict[str, int]) -> dict[str, tuple[int, ...]]:
    """Per-operand element footprint of one tile under ``tiling``."""
    fp = {}
    for p in plans:
        s = cdlt.surrogates[p.surrogate]
        extents = {var: tiling.get(var, _loop_range(cdlt, var))
                   for var in _ref_vars(p.ref)}
        fp[p.surrogate] = ref_footprint(p.ref, s, extents)
    return fp


def _ref_vars(r: Ref) -> set[str]:
    out = set()
    for ix in r.idx:
        out |= ix.vars()
    return out


def _loop_range(cdlt: Codelet, var: str) -> int:
    return cdlt.loop(var).trips


def validate_tiling(cdlt: Codelet, acg: ACG, plans: list[OperandPlan],
                    tiling: dict[str, int], pad_align: bool = False) -> bool:
    """Algorithm 1 body: alignment + cumulative capacity over storage nodes.

    ``pad_align=True`` is the §4 zero-padding fallback: misaligned transfer
    sizes are rounded up to the source ``data_width`` (consuming the padded
    size in the capacity check) instead of invalidating the tiling.  It is
    only used when strict Algorithm-1 admits no tiling at all.
    """
    storage: dict[str, int] = {m.name: 0 for m in acg.memory_nodes()}
    fps = _tile_footprints(cdlt, plans, tiling)
    for p in plans:
        s = cdlt.surrogates[p.surrogate]
        bits = math.prod(fps[p.surrogate]) * s.dtype.bits
        for edge, charge in p.hops(acg):
            src_m = acg.memory(edge.src)
            dst_m = acg.memory(charge)
            if bits % src_m.data_width != 0:
                if not pad_align:
                    return False
                bits = math.ceil(bits / src_m.data_width) * src_m.data_width
            storage[charge] += bits
            if not dst_m.offchip and storage[charge] > dst_m.capacity_bits:
                return False
    return True


def enumerate_tilings(cdlt: Codelet, acg: ACG, plans: list[OperandPlan],
                      max_candidates: int = 4000, pad_align: bool = False
                      ) -> list[dict[str, int]]:
    """All valid tilings over divisor grids of each loop range (pruned)."""
    loops = [l for l in cdlt.loops()]
    grids = []
    for l in loops:
        ds = _divisors(l.trips)
        grids.append([(l.var, d) for d in ds])
    valid = []
    count = 0
    for combo in itertools.product(*grids):
        count += 1
        if count > max_candidates * 50:
            break
        tiling = dict(combo)
        if validate_tiling(cdlt, acg, plans, tiling, pad_align):
            valid.append(tiling)
            if len(valid) >= max_candidates:
                break
    return valid


def choose_tiling(cdlt: Codelet, acg: ACG, plans: list[OperandPlan],
                  cost_fn) -> dict[str, int]:
    cands = enumerate_tilings(cdlt, acg, plans)
    if not cands:
        # §4 padding fallback: odd-sized tensors on wide-data_width memories
        cands = enumerate_tilings(cdlt, acg, plans, pad_align=True)
        if cands:
            cdlt.note("choose_tiling: strict Algorithm-1 empty; "
                      "using zero-padded transfer alignment (§4)")
    if not cands:
        raise ValueError(
            f"Algorithm 1 found no valid tiling for {cdlt.name} on {acg.name}")
    best, best_cost = None, None
    for t in cands:
        c = cost_fn(cdlt, acg, plans, t)
        if best_cost is None or c < best_cost:
            best, best_cost = t, c
    cdlt.note(f"choose_tiling: {best} est_cost={best_cost:.0f} "
              f"({len(cands)} valid candidates)")
    return best


def estimate_tiling_cost(cdlt: Codelet, acg: ACG, plans: list[OperandPlan],
                         tiling: dict[str, int]) -> float:
    """Transfer + compute cycle estimate used for tile selection.

    Mirrors the analytic cost model's transfer accounting: each operand's tile
    is re-loaded once per iteration of every tile loop *outside or at* its
    insertion level (reuse across inner loops it does not depend on).
    """
    loops = cdlt.loops()
    order = [l.var for l in loops]
    trips = {l.var: math.ceil(l.trips / tiling.get(l.var, l.trips)) for l in loops}
    fps = _tile_footprints(cdlt, plans, tiling)
    total = 0.0
    for p in plans:
        s = cdlt.surrogates[p.surrogate]
        bits = math.prod(fps[p.surrogate]) * s.dtype.bits
        vars_ = _ref_vars(p.ref)
        # innermost tile loop this operand depends on
        level = max((order.index(v0) for v0 in vars_ if v0 in order), default=-1)
        n_loads = math.prod([trips[v0] for v0 in order[: level + 1]]) or 1
        factor = 2 if p.is_output else 1  # alloc/load + writeback
        for e, _charge in p.hops(acg):
            total += factor * n_loads * e.transfer_ops(bits) * e.latency
    # compute cycles at current granularity
    (loops_c, op), = cdlt.computes()
    g = op.cap_obj.geometry
    work = math.prod(l.trips for l in loops)
    per_inv = math.prod(g) if g else op.cap_obj.out_elems
    total += (work / per_inv) * op.cap_obj.cycles
    return total


# ---------------------------------------------------------------------------
# The schedule-point space (search substrate)
# ---------------------------------------------------------------------------


@dataclasses.dataclass
class ScheduleSpace:
    """The Algorithm-1-valid schedule-point space of one (codelet, target).

    ``tilings`` are the enumerated valid tilings; ``divisors`` maps each loop
    var to its (pruned) divisor grid — the neighbourhood structure mutation
    operators move through; ``valid`` re-checks any mutated tiling against
    Algorithm 1, so strategies may step outside the enumerated list as long
    as they stay inside the valid region.
    """

    tilings: list[dict[str, int]]
    divisors: dict[str, list[int]]
    pad_align: bool
    probe: Codelet                 # placed+mapped (pre-tiling) codelet
    acg: ACG
    plans: list[OperandPlan]

    def valid(self, tiling: dict[str, int]) -> bool:
        return validate_tiling(self.probe, self.acg, self.plans, tiling,
                               pad_align=self.pad_align)

    # -- prefix enumeration (the beam-search substrate) ----------------------
    def loop_order(self) -> list[str]:
        """Loop vars in nest order — the order beam search commits tiling
        decisions (outermost first, matching ``split_loops``' tile-loop
        order)."""
        return [l.var for l in self.probe.loops()]

    def prefixes(self, depth: int,
                 within: "list[tuple] | None" = None) -> list[tuple]:
        """Distinct ``depth``-long factor prefixes (in loop order) of the
        enumerated valid tilings; ``within`` restricts to prefixes that
        extend one of the given ``depth-1``-long prefixes.  Every returned
        prefix has at least one valid completion by construction — beam
        pruning never strands itself on an infeasible partial schedule."""
        order = self.loop_order()[:depth]
        allowed = set(within) if within is not None else None
        out: dict[tuple, None] = {}
        for t in self.tilings:
            vec = tuple(t[v] for v in order)
            if allowed is not None and vec[:-1] not in allowed:
                continue
            out[vec] = None
        return sorted(out)

    def committed(self, prefix: tuple) -> dict[str, int]:
        """A factor prefix (aligned with ``loop_order()``) as a partial
        tiling dict — the ``committed`` argument of ``cost.prefix_bound``."""
        return dict(zip(self.loop_order(), prefix))

    def signature(self) -> str:
        """Shape identity of this schedule space: loop order, ranges and
        divisor grids.  Two layers with equal signatures admit exactly the
        same schedule points, so recorded best points transfer verbatim —
        the warm-start index groups by this."""
        import hashlib
        parts = [f"{l.var}:{l.trips}:{','.join(map(str, self.divisors[l.var]))}"
                 for l in self.probe.loops()]
        parts.append(f"pad={int(self.pad_align)}")
        return hashlib.sha256(";".join(parts).encode()).hexdigest()[:16]


def schedule_space(cdlt: Codelet, acg: ACG, *, options=None, pipeline=None,
                   max_candidates: int = 2000) -> ScheduleSpace:
    """Enumerate the valid schedule-point space by running the pipeline's
    pre-tiling prefix (every stage before ``tile``, including any spliced
    target hooks) on a probe clone and applying Algorithm 1 over the
    divisor grids — the probe sees exactly what candidate materialisation
    will see."""
    from .pipeline import CompileOptions, PassContext, Pipeline

    ctx = PassContext(cdlt.clone(), acg, options or CompileOptions())
    pl = pipeline or Pipeline.default().with_acg_hooks(acg)
    names = pl.names
    if "tile" in names:
        pl.run(ctx, skip=names[names.index("tile"):])
    else:
        pl.run(ctx, until="map_compute")
    plans = plan_operands(ctx.cdlt, acg)
    pad = False
    tilings = enumerate_tilings(ctx.cdlt, acg, plans,
                                max_candidates=max_candidates)
    if not tilings:
        pad = True
        tilings = enumerate_tilings(ctx.cdlt, acg, plans,
                                    max_candidates=max_candidates,
                                    pad_align=True)
    divisors = {l.var: _divisors(l.trips) for l in ctx.cdlt.loops()}
    return ScheduleSpace(tilings, divisors, pad, ctx.cdlt, acg, plans)


# ---------------------------------------------------------------------------
# Stage 4: loop splitting into the canonical tiled nest
# ---------------------------------------------------------------------------

INTRA_SUFFIX = "_i"


def split_loops(cdlt: Codelet, tiling: dict[str, int]) -> None:
    """Rebuild the body as tile-loops(outer) -> intra-loops -> compute.

    An original loop ``x`` with range R and tile t < R becomes
    ``loop x(0,R,t){ ... loop x_i(0,t,1){ ... } }`` with refs rewritten
    ``x -> x + x_i``.  Loops whose tile equals their range stay as single
    intra loops (no outer twin, no rewrite).
    """
    (loops, op), = cdlt.computes()
    orig = list(loops)
    cdlt.tiling = dict(tiling)
    tiled = {l.var: tiling[l.var] for l in orig
             if tiling.get(l.var, l.trips) < l.trips}

    def rewrite(r: Ref) -> Ref:
        new_idx = []
        for ix in r.idx:
            e = Aff(ix.terms, ix.const)
            for var, coeff in ix.terms:
                if var in tiled:
                    e = e + Aff(((var + INTRA_SUFFIX, coeff),), 0)
            new_idx.append(e)
        return Ref(r.var, tuple(new_idx), r.sizes)

    new_op = Compute(op.capability, rewrite(op.out),
                     tuple(rewrite(i) for i in op.ins), op.loc,
                     dict(op.roles), op.cap_obj, op.dtype)
    # intra roles: the split moves tiled role vars to their intra twins
    new_op.roles = {
        role: [(var + INTRA_SUFFIX) if var in tiled else var for var in vars_]
        for role, vars_ in op.roles.items()
    }

    body: list = [new_op]
    for l in reversed(orig):  # intra loops, innermost-first wrap
        if l.var in tiled:
            body = [Loop(l.var + INTRA_SUFFIX, 0, tiled[l.var], 1, body, role="intra")]
        else:
            body = [Loop(l.var, 0, l.trips, 1, body, role="intra")]
    for l in reversed(orig):  # tile loops
        if l.var in tiled:
            body = [Loop(l.var, 0, l.trips, tiled[l.var], body, role="tile")]
    cdlt.body = body
    cdlt.note(f"split_loops: tiling={tiling}")


# ---------------------------------------------------------------------------
# Stage 5: transfer insertion
# ---------------------------------------------------------------------------


def insert_transfers(cdlt: Codelet, acg: ACG, plans: list[OperandPlan]) -> None:
    tile_loops = [l for l in cdlt.loops() if l.role == "tile"]
    order = [l.var for l in tile_loops]
    (_, op), = cdlt.computes()
    # per-tile footprints: tile-loop vars are fixed bases (extent 1), all
    # inner loops (intra twins + untiled full loops) contribute their trips
    intra_trips = {l.var: l.trips for l in cdlt.loops() if l.role == "intra"}
    fps: dict[str, tuple[int, ...]] = {}
    for p in plans:
        s = cdlt.surrogates[p.surrogate]
        extents = {var: intra_trips.get(var, 1) for var in _ref_vars(p.ref)}
        fps[p.surrogate] = ref_footprint(p.ref, s, extents)

    def insertion_body(vars_: set[str]) -> list:
        level = max((order.index(v0) for v0 in vars_ if v0 in order), default=-1)
        return cdlt.body if level < 0 else tile_loops[level].body

    local_of: dict[str, str] = {}
    for p in plans:
        s = cdlt.surrogates[p.surrogate]
        sizes = fps[p.surrogate]
        vars_ = _ref_vars(p.ref) & set(order)
        body = insertion_body(vars_)
        # index of the tile base (outer vars only)
        base_idx = tuple(
            Aff(tuple((vv, c) for vv, c in ix.terms if vv in order), ix.const)
            for ix in p.ref.idx
        )
        prev_name, prev_loc = p.surrogate, p.path[0]
        loads: list[Transfer] = []
        for hop_dst in p.path[1:]:
            lname = cdlt.fresh_name(p.surrogate + "_")
            cdlt.local(lname, sizes, s.dtype, hop_dst)
            src_ref = Ref(prev_name,
                          base_idx if prev_name == p.surrogate else (),
                          sizes)
            if p.is_output:
                # allocation transfer with const fill (accumulator tile)
                loads.append(Transfer(Ref("", (), None), sizes, dst_loc=hop_dst,
                                      alloc=lname, fill=0))
            else:
                loads.append(Transfer(src_ref, sizes, dst_loc=hop_dst, alloc=lname))
            local_of[p.surrogate] = lname
            prev_name, prev_loc = lname, hop_dst
        for t in reversed(loads):
            body.insert(0, t)
        if p.is_output:
            # write-back chain staging -> ... -> home, appended after the nest
            back = list(reversed(p.path))
            prev = local_of[p.surrogate]
            for nxt in back[1:]:
                if nxt == p.path[0]:
                    dst_ref = Ref(p.surrogate, base_idx, sizes)
                else:
                    lname = cdlt.fresh_name(p.surrogate + "_")
                    cdlt.local(lname, sizes, s.dtype, nxt)
                    dst_ref = Ref(lname, (), sizes)
                body.append(Transfer(Ref(prev, (), sizes), sizes, dst=dst_ref))
                prev = dst_ref.var

    # retarget the compute op onto the staged locals (intra index space)
    def localize(r: Ref) -> Ref:
        if r.var not in local_of:
            return r
        new_idx = tuple(
            Aff(tuple((vv, c) for vv, c in ix.terms if vv not in order), 0)
            for ix in r.idx
        )
        return Ref(local_of[r.var], new_idx, r.sizes)

    op.out = localize(op.out)
    op.ins = tuple(localize(i) for i in op.ins)
    cdlt.note(f"insert_transfers: staged {sorted(local_of)} -> "
              f"{[local_of[k] for k in sorted(local_of)]}")


# ---------------------------------------------------------------------------
# Entry point
# ---------------------------------------------------------------------------


def schedule(cdlt: Codelet, acg: ACG, config=None) -> Codelet:
    """Thin stable wrapper over the named pass pipeline (``pipeline.py``):
    runs every stage but ``codegen`` on a clone and returns it.  ``config``
    is a ``CompileOptions`` (the old ``ScheduleConfig``, kept as an alias).
    """
    from .pipeline import CompileOptions, PassContext, Pipeline

    config = config or CompileOptions()
    ctx = PassContext(cdlt.clone(), acg, config)
    Pipeline.default().with_acg_hooks(acg).run(ctx, skip=("codegen",))
    ctx.cdlt.note(f"schedule: done (vectorize={config.vectorize}, "
                  f"unroll={config.unroll}, pack={config.pack})")
    return ctx.cdlt


def __getattr__(name: str):
    # ScheduleConfig was unified into pipeline.CompileOptions; keep the old
    # import path (``from repro.core.scheduler import ScheduleConfig``) alive.
    if name == "ScheduleConfig":
        from .pipeline import CompileOptions
        return CompileOptions
    raise AttributeError(f"module {__name__!r} has no attribute {name!r}")


__all__ = ["OperandPlan", "ScheduleConfig", "ScheduleSpace",
           "capability_candidates", "choose_tiling", "enumerate_tilings",
           "estimate_tiling_cost", "insert_transfers", "map_compute",
           "place_operands", "plan_operands", "schedule", "schedule_space",
           "split_loops", "validate_tiling"]
