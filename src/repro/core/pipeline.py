"""Pluggable compilation pipeline: named, registered passes over Codelets.

The paper's central claim is that the ACG makes compilation workflows
*adaptable* — a new accelerator brings attributes (and rarely a pass), not a
new compiler.  This module is the seam that realises the claim as an API:

* every Covenant stage is a **named, registered pass** ``(PassContext) ->
  None`` (``place``, ``map_compute``, ``tile``, ``split``, ``transfers``,
  ``granularize``, ``vectorize``, ``unroll``, ``pack``, ``codegen``), each a
  thin orchestration shim over the existing scheduler/passes/codegen
  machinery;
* a ``Pipeline`` is an ordered list of such passes with functional edit
  operations (``override`` / ``insert_before`` / ``insert_after`` /
  ``without``) — BYOC-style: targets extend the stock flow instead of
  redeveloping it;
* an ACG may carry per-target hooks (``acg.pass_overrides`` replaces a stage
  body, ``acg.extra_passes`` splices new stages at a named position);
  ``Pipeline.with_acg_hooks`` applies them, and ``repro.compile`` does so by
  default;
* ``CompileOptions`` is the single frozen knob set for the whole flow — the
  unification of the old ``ScheduleConfig`` (which remains importable as an
  alias) with the codegen limits that used to travel as loose kwargs.

Stages honour ``CompileOptions`` gating internally (e.g. the ``vectorize``
stage is a no-op when ``options.vectorize`` is false), so one pipeline
serves every configuration and overrides see the full context.
"""
from __future__ import annotations

import dataclasses
from typing import Callable, Sequence

from .acg import ACG
from .codelet import Codelet

# ---------------------------------------------------------------------------
# options — the ScheduleConfig/loose-kwargs unification
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CompileOptions:
    """All knobs of one compile, hashable so it can key the compile cache.

    ``vectorize`` / ``unroll`` / ``pack`` / ``unroll_factor`` are the old
    ``ScheduleConfig`` fields (Fig-12 optimization toggles); ``max_mnemonics``
    is the stream-size guard that used to be a ``codegen.generate`` kwarg.

    ``search`` (a ``repro.core.search.SearchOptions``) routes the compile
    through schedule search instead of the one-shot heuristic — the searched
    winner is cached under the same content-addressed key scheme (the search
    options are part of the key).  ``store`` names a disk-backed
    ``ArtifactStore`` (instance or directory path); it is a *location*, not a
    compile input, so it does not contribute to the fingerprint.
    """

    vectorize: bool = True
    unroll: bool = True
    pack: bool = True
    unroll_factor: int = 4
    max_mnemonics: int = 300_000
    check_covenant: bool = True    # run the early covenant-validation stage
    search: object | None = None   # SearchOptions; None = one-shot heuristic
    store: object | None = None    # ArtifactStore | path; not fingerprinted

    def fingerprint(self) -> str:
        base = repr((self.vectorize, self.unroll, self.pack,
                     self.unroll_factor, self.max_mnemonics,
                     self.check_covenant))
        if self.search is not None:
            fp = getattr(self.search, "fingerprint", None)
            base += ";search=" + (fp() if fp else repr(self.search))
        return base


@dataclasses.dataclass
class PassContext:
    """Mutable state threaded through the pipeline.

    ``cdlt`` is transformed in place (it is always a clone of the caller's
    codelet); ``state`` carries inter-stage products (``plans``, ``tiling``,
    ``pack``, ``program``); ``executed`` logs stage names for introspection.

    ``overrides`` injects a *schedule point* as data: ``{"tiling": {var:
    factor}, "unroll_factor": n}`` makes the ``tile`` stage adopt the given
    tiling instead of running Algorithm-1 selection and the ``unroll`` stage
    use the given factor.  This is how schedule search materialises
    candidates and how the artifact store replays a stored schedule.
    """

    cdlt: Codelet
    acg: ACG
    options: CompileOptions
    state: dict = dataclasses.field(default_factory=dict)
    executed: list = dataclasses.field(default_factory=list)
    overrides: dict = dataclasses.field(default_factory=dict)


class PipelineError(ValueError):
    """A pipeline edit or ACG hook referenced a stage that does not exist
    (or used a malformed splice position)."""


StageFn = Callable[[PassContext], None]

# name -> stage function; targets and users can register additional stages.
STAGES: dict[str, StageFn] = {}


def register_stage(name: str) -> Callable[[StageFn], StageFn]:
    def deco(fn: StageFn) -> StageFn:
        STAGES[name] = fn
        return fn
    return deco


# ---------------------------------------------------------------------------
# the stock Covenant stages (§3.2 scheduling, §4 optimizations, §3.3 codegen)
# ---------------------------------------------------------------------------


@register_stage("covenant")
def covenant_stage(ctx: PassContext) -> None:
    """Early covenant validation (§2): every compute op must have a
    supporting capability, an encodable mnemonic and a viable staging
    route *before* scheduling starts, so a broken covenant surfaces as a
    named ``CovenantError`` diagnostic instead of a KeyError deep in
    tiling or codegen.  Disable with ``CompileOptions(check_covenant=
    False)``."""
    if not getattr(ctx.options, "check_covenant", True):
        return
    from .covenant import check_covenant
    check_covenant(ctx.cdlt, ctx.acg, options=ctx.options)


@register_stage("place")
def place_stage(ctx: PassContext) -> None:
    from .scheduler import place_operands
    place_operands(ctx.cdlt, ctx.acg)


@register_stage("map_compute")
def map_compute_stage(ctx: PassContext) -> None:
    from .scheduler import map_compute
    map_compute(ctx.cdlt, ctx.acg, vectorize=ctx.options.vectorize)


@register_stage("tile")
def tile_stage(ctx: PassContext) -> None:
    from .scheduler import choose_tiling, estimate_tiling_cost, plan_operands
    plans = plan_operands(ctx.cdlt, ctx.acg)
    ctx.state["plans"] = plans
    override = ctx.overrides.get("tiling")
    if override is not None:
        # the schedule point is data: adopt the injected tiling verbatim
        # (search candidates come pre-validated by Algorithm 1; store
        # replays record a tiling that was valid when first compiled)
        ctx.state["tiling"] = dict(override)
        ctx.cdlt.note(f"tile: injected tiling={dict(override)}")
    else:
        ctx.state["tiling"] = choose_tiling(ctx.cdlt, ctx.acg, plans,
                                            estimate_tiling_cost)


@register_stage("split")
def split_stage(ctx: PassContext) -> None:
    from .scheduler import split_loops
    split_loops(ctx.cdlt, ctx.state["tiling"])


@register_stage("transfers")
def transfers_stage(ctx: PassContext) -> None:
    from .scheduler import insert_transfers, plan_operands
    # refs were rewritten by the split; re-plan before materialising moves
    plans = plan_operands(ctx.cdlt, ctx.acg)
    ctx.state["plans"] = plans
    insert_transfers(ctx.cdlt, ctx.acg, plans)


@register_stage("granularize")
def granularize_stage(ctx: PassContext) -> None:
    from .passes import granularize
    granularize(ctx.cdlt, ctx.acg)


@register_stage("vectorize")
def vectorize_stage(ctx: PassContext) -> None:
    if not ctx.options.vectorize:
        return
    from .passes import vectorize
    vectorize(ctx.cdlt, ctx.acg)


@register_stage("unroll")
def unroll_stage(ctx: PassContext) -> None:
    if not ctx.options.unroll:
        return
    factor = ctx.overrides.get("unroll_factor", ctx.options.unroll_factor)
    if factor <= 1:
        return
    from .passes import unroll
    unroll(ctx.cdlt, ctx.acg, factor)


@register_stage("pack")
def pack_stage(ctx: PassContext) -> None:
    # packing is applied at analysis/execution time (cost model II bound,
    # stream packet former); this stage records the decision for consumers.
    ctx.state["pack"] = bool(ctx.options.pack) and ctx.acg.issue_slots > 1


@register_stage("codegen")
def codegen_stage(ctx: PassContext) -> None:
    from .codegen import generate
    ctx.state["program"] = generate(
        ctx.cdlt, ctx.acg, max_mnemonics=ctx.options.max_mnemonics,
        macros=ctx.state.get("macros"))


# The stock stage order.  ``SCHEDULE_STAGES`` is the prefix the legacy
# ``scheduler.schedule`` wrapper runs (everything but code generation).
DEFAULT_STAGE_ORDER: tuple[str, ...] = (
    "covenant", "place", "map_compute", "tile", "split", "transfers",
    "granularize", "vectorize", "unroll", "pack", "codegen",
)
SCHEDULE_STAGES: tuple[str, ...] = DEFAULT_STAGE_ORDER[:-1]


# ---------------------------------------------------------------------------
# Pipeline
# ---------------------------------------------------------------------------


def _capture_tag(value) -> str:
    """Identity contribution of one captured closure value / defaults
    tuple.  ``repr`` is used when it is faithful; a repr that raises or
    elides content (numpy's ``...`` truncation) falls back to object id —
    process-local, so distinct values never alias (the safe direction)."""
    try:
        r = repr(value)
    except Exception:
        return f"@{id(value):x}"
    if "..." in r:
        return f"@{id(value):x}"
    return r


class Pipeline:
    """An ordered list of named passes; edit operations return new Pipelines
    (the default pipeline is shared, so edits must not mutate in place)."""

    def __init__(self, stages: Sequence[tuple[str, StageFn]]):
        self.stages: list[tuple[str, StageFn]] = list(stages)

    @classmethod
    def default(cls) -> "Pipeline":
        return cls([(n, STAGES[n]) for n in DEFAULT_STAGE_ORDER])

    @property
    def names(self) -> list[str]:
        return [n for n, _ in self.stages]

    def _index(self, name: str) -> int:
        for i, (n, _) in enumerate(self.stages):
            if n == name:
                return i
        raise PipelineError(
            f"no stage {name!r} in pipeline; stages: {self.names}")

    # -- functional edits ----------------------------------------------------
    def override(self, name: str, fn: StageFn) -> "Pipeline":
        """Replace the body of stage ``name`` (BYOC-style target override)."""
        i = self._index(name)
        out = list(self.stages)
        out[i] = (name, fn)
        return Pipeline(out)

    def insert_after(self, anchor: str, name: str, fn: StageFn) -> "Pipeline":
        i = self._index(anchor)
        out = list(self.stages)
        out.insert(i + 1, (name, fn))
        return Pipeline(out)

    def insert_before(self, anchor: str, name: str, fn: StageFn) -> "Pipeline":
        i = self._index(anchor)
        out = list(self.stages)
        out.insert(i, (name, fn))
        return Pipeline(out)

    def without(self, name: str) -> "Pipeline":
        i = self._index(name)
        out = list(self.stages)
        del out[i]
        return Pipeline(out)

    def with_acg_hooks(self, acg: ACG) -> "Pipeline":
        """Apply a target's pass hooks: ``acg.pass_overrides`` (stage name ->
        replacement fn) and ``acg.extra_passes`` (("after:STAGE" |
        "before:STAGE", name, fn) splices)."""
        pl = self
        for name, fn in getattr(acg, "pass_overrides", {}).items():
            pl = pl.override(name, fn)
        for position, name, fn in getattr(acg, "extra_passes", ()):
            where, _, anchor = position.partition(":")
            if where == "after":
                pl = pl.insert_after(anchor, name, fn)
            elif where == "before":
                pl = pl.insert_before(anchor, name, fn)
            else:
                raise PipelineError(
                    f"extra pass {name!r}: position must be "
                    f"'after:STAGE' or 'before:STAGE', got {position!r}")
        return pl

    # -- execution -----------------------------------------------------------
    def run(self, ctx: PassContext, until: str | None = None,
            skip: Sequence[str] = ()) -> PassContext:
        """Run stages in order.  ``until`` stops after the named stage
        (inclusive); ``skip`` omits stages by name (used by the driver to
        defer ``codegen`` until the artifact's program is first needed)."""
        for name, fn in self.stages:
            if name not in skip:
                fn(ctx)
                ctx.executed.append(name)
            if name == until:
                break
        return ctx

    def run_stage(self, name: str, ctx: PassContext) -> PassContext:
        """Run a single stage by name (e.g. deferred ``codegen``)."""
        _, fn = self.stages[self._index(name)]
        fn(ctx)
        ctx.executed.append(name)
        return ctx

    def fingerprint(self) -> str:
        """Cache-key contribution.  Stock stages are identified by name;
        custom functions by qualname + a hash of their source *plus* their
        default args and captured closure values, which is stable across
        processes — required for the disk artifact store to give
        BYOC/custom-target compiles warm hits — while two closures from
        the same factory with different captured parameters still get
        distinct keys.  Captures whose ``repr`` embeds object addresses
        hash process-locally (never a cross-process hit — the safe
        direction); callers mutating closure state after compiling should
        pass ``cache=False`` to ``repro.compile``.  Functions without
        retrievable source (REPL, ``exec``) fall back to ``id``."""
        import hashlib
        import inspect

        parts = []
        for name, fn in self.stages:
            if STAGES.get(name) is fn:
                parts.append(name)
                continue
            try:
                ident = [inspect.getsource(fn)]
            except (OSError, TypeError):
                ident = [f"@{id(fn):x}"]
            if getattr(fn, "__defaults__", None):
                ident.append(_capture_tag(fn.__defaults__))
            for cell in getattr(fn, "__closure__", None) or ():
                try:
                    ident.append(_capture_tag(cell.cell_contents))
                except ValueError:
                    ident.append("<empty-cell>")
            tag = hashlib.sha256(
                "\x00".join(ident).encode()).hexdigest()[:16]
            parts.append(f"{name}:{getattr(fn, '__qualname__', '?')}:{tag}")
        return ";".join(parts)

    def __repr__(self) -> str:
        return f"Pipeline({' -> '.join(self.names)})"


__all__ = ["CompileOptions", "DEFAULT_STAGE_ORDER", "PassContext", "Pipeline",
           "PipelineError", "SCHEDULE_STAGES", "STAGES", "register_stage"]
