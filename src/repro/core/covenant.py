"""Covenant validation — does this codelet have a lawful mapping onto this
ACG, and is the ACG itself sound?

Before this module, a broken covenant surfaced as a ``KeyError`` deep in
scheduling or code generation (a missing mnemonic three passes after the
decision that needed it, an undersized scratchpad as "Algorithm 1 found no
valid tiling").  ``check_covenant`` runs the same capability / mnemonic /
staging-path / footprint matching *up front*, as the first pipeline stage,
and reports every violation with the name of the thing that is missing or
too small plus a hint about what would fix it.

Two layers:

* ``validate_acg(acg)``     — the target alone: structural spec checks
  (via ``spec.validate_spec`` on a snapshot) plus graph reachability that
  only a built graph can answer (home memory resolvable, every compute
  node round-trip reachable from the operand home).
* ``check_covenant(cdlt, acg)`` — the pairing: every compute op must have
  a supporting capability, a mnemonic to encode it, a staging route for
  each operand, and staging memories big enough for one invocation tile.
"""
from __future__ import annotations

import dataclasses

import networkx as nx

from .acg import ACG, MemoryNode
from .codelet import Codelet

# ---------------------------------------------------------------------------
# diagnostics
# ---------------------------------------------------------------------------


@dataclasses.dataclass(frozen=True)
class CovenantViolation:
    """One named break in the covenant.

    ``kind`` is the violation class (``capability`` / ``mnemonic`` /
    ``memory`` / ``path`` / ``structure``), ``subject`` the ACG or codelet
    entity at fault, ``message`` the failure, ``hint`` what would repair it.
    """

    kind: str
    subject: str
    message: str
    hint: str = ""

    def __str__(self) -> str:
        s = f"[{self.kind}] {self.subject}: {self.message}"
        if self.hint:
            s += f" ({self.hint})"
        return s


class CovenantError(ValueError):
    """The covenant between a codelet and an ACG is broken; ``violations``
    carries the structured diagnostics."""

    def __init__(self, cdlt_name: str, acg_name: str,
                 violations: list[CovenantViolation]):
        self.cdlt_name = cdlt_name
        self.acg_name = acg_name
        self.violations = list(violations)
        bullet = "\n  - ".join(str(v) for v in self.violations)
        super().__init__(
            f"broken covenant: codelet {cdlt_name!r} cannot map onto "
            f"ACG {acg_name!r}:\n  - {bullet}")


# ---------------------------------------------------------------------------
# target-only validation
# ---------------------------------------------------------------------------


def validate_acg(acg: ACG, *, raise_on_error: bool = True) -> list[str]:
    """Structural + reachability checks over a built ACG.  Returns the
    problem list; raises ``spec.SpecError`` on problems unless told not to."""
    from .spec import SpecError, validate_spec

    problems = validate_spec(acg.to_spec(), raise_on_error=False)
    try:
        home = acg.highest_memory()
    except ValueError as e:
        problems.append(str(e))
        home = None
    if home is not None:
        for cu in acg.compute_nodes():
            try:
                acg.shortest_path(home.name, cu.name)
            except (nx.NetworkXNoPath, nx.NodeNotFound):
                problems.append(
                    f"compute {cu.name}: unreachable from the operand home "
                    f"{home.name} — inputs cannot be staged")
            try:
                acg.shortest_path(cu.name, home.name)
            except (nx.NetworkXNoPath, nx.NodeNotFound):
                problems.append(
                    f"compute {cu.name}: no route back to the operand home "
                    f"{home.name} — outputs cannot be written back")
    if problems and raise_on_error:
        raise SpecError(acg.name, problems)
    return problems


# ---------------------------------------------------------------------------
# codelet-vs-ACG validation
# ---------------------------------------------------------------------------


def _staging_memory(acg: ACG, path: list[str]) -> MemoryNode | None:
    """Last memory node on a home->compute path (the staging buffer)."""
    mems = [acg.nodes[n] for n in path
            if isinstance(acg.nodes[n], MemoryNode)]
    return mems[-1] if mems else None


def check_covenant(cdlt: Codelet, acg: ACG, options=None, *,
                   raise_on_error: bool = True) -> list[CovenantViolation]:
    """Verify every compute op of ``cdlt`` has a lawful mapping onto
    ``acg``: a supporting capability, an encodable mnemonic, a staging
    route per operand, and staging memories that can hold at least one
    invocation tile.  Runs before placement (the ``covenant`` pipeline
    stage), so it reasons from the hypothetical mapping compute-mapping
    would pick — widest capability under ``options.vectorize`` (the
    default), narrowest otherwise.
    """
    from .scheduler import capability_candidates

    violations: list[CovenantViolation] = []
    try:
        home = acg.highest_memory()
    except ValueError as e:
        violations.append(CovenantViolation(
            "structure", acg.name, str(e),
            hint="declare at least one memory node reaching a compute node"))
        home = None
    vectorize = getattr(options, "vectorize", True)

    required = ["XFER", "ALLOC"] + (["LOOPI"] if acg.loop_overhead > 0 else [])
    for name in required:
        if name not in acg.mnemonics:
            violations.append(CovenantViolation(
                "mnemonic", name,
                f"ACG {acg.name!r} defines no {name!r} mnemonic, which "
                f"transfer/loop code generation requires",
                hint="add it to the spec's mnemonics (see "
                     "spec.common_mnemonics)"))

    for _, op in cdlt.computes():
        cands = capability_candidates(acg, op)
        if not cands:
            have = sorted({c.name for n in acg.compute_nodes()
                           for c in n.capabilities})
            violations.append(CovenantViolation(
                "capability", op.capability,
                f"no compute node of ACG {acg.name!r} supports capability "
                f"{op.capability!r} at dtype {op.dtype}",
                hint=f"declared capabilities: {have}"))
            continue
        node, capo = cands[0] if vectorize else cands[-1]
        if capo.name not in acg.mnemonics and \
                op.capability not in acg.mnemonics:
            violations.append(CovenantViolation(
                "mnemonic", capo.name,
                f"capability {capo.name!r} on node {node.name} has no "
                f"mnemonic definition (nor has its codelet alias "
                f"{op.capability!r})",
                hint=f"defined mnemonics: {sorted(acg.mnemonics)}"))
        if home is None:
            continue

        ports = acg.operand_ports.get((node.name, capo.name))
        refs = list(op.ins) + [op.out]
        cap_ops = list(capo.inputs) + list(capo.outputs)
        seen: set[str] = set()
        for i, r in enumerate(refs):
            s = cdlt.surrogates.get(r.var)
            if s is None or s.kind == "param" or r.var in seen:
                continue
            seen.add(r.var)
            src = s.loc or home.name
            is_out = s.kind == "out"
            if ports is not None:
                staging_name = ports[min(i, len(ports) - 1)]
                if staging_name not in acg.nodes:
                    violations.append(CovenantViolation(
                        "path", staging_name,
                        f"operand_ports for ({node.name}, {capo.name}) "
                        f"names unknown node {staging_name!r}"))
                    continue
                route = (staging_name, src) if is_out else (src, staging_name)
            else:
                route = (node.name, src) if is_out else (src, node.name)
            try:
                path = acg.shortest_path(*route)
            except (nx.NetworkXNoPath, nx.NodeNotFound):
                violations.append(CovenantViolation(
                    "path", r.var,
                    f"no ACG route {route[0]} -> {route[1]} to stage "
                    f"operand {r.var!r} for {capo.name} on {node.name}",
                    hint="connect the nodes (spec edges) or set "
                         "operand_ports"))
                continue
            staging = _staging_memory(
                acg, list(reversed(path)) if is_out else path)
            if staging is None or staging.offchip:
                continue
            cap_op = cap_ops[min(i, len(cap_ops) - 1)]
            elems = cap_op.elems
            if s.shape is not None:
                elems = min(elems, s.elems)
            dtype_bits = s.dtype.bits if s.dtype is not None \
                else cap_op.dtype.bits
            need = elems * dtype_bits
            if need > staging.capacity_bits:
                violations.append(CovenantViolation(
                    "memory", staging.name,
                    f"memory node {staging.name} "
                    f"({staging.capacity_bits} bits) cannot hold one "
                    f"{capo.name} invocation tile of operand {r.var!r} "
                    f"({need} bits)",
                    hint=f"grow {staging.name} (depth/banks) or drop to a "
                         f"smaller-granularity capability"))

    if violations and raise_on_error:
        raise CovenantError(cdlt.name, acg.name, violations)
    return violations


__all__ = ["CovenantError", "CovenantViolation", "check_covenant",
           "validate_acg"]
