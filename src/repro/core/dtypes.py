"""Datatype vocabulary shared by the ACG, Codelets and both backends.

The paper's capability signatures are granularity-typed: ``(i16,2)=ADD((i16,2),(i16,2))``.
``Dtype`` carries the bit-width (drives Algorithm-1 alignment checks and
memory-occupancy accounting) plus numpy/jax views for the functional
simulator and the JAX backend.
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class Dtype:
    name: str
    bits: int
    kind: str  # "int" | "uint" | "float"

    @property
    def bytes(self) -> int:
        return max(1, self.bits // 8)

    @property
    def np(self) -> np.dtype:
        if self.name == "bf16":
            # numpy has no bfloat16; the simulator carries bf16 payloads in f32
            # and the JAX backend uses jnp.bfloat16 natively.
            return np.dtype(np.float32)
        return np.dtype(self.name.replace("i", "int").replace("u", "uint").replace("f", "float"))

    def jnp(self):
        import jax.numpy as jnp

        return {
            "i8": jnp.int8, "u8": jnp.uint8, "i16": jnp.int16, "u16": jnp.uint16,
            "i32": jnp.int32, "u32": jnp.uint32, "f32": jnp.float32,
            "bf16": jnp.bfloat16, "f16": jnp.float16,
        }[self.name]

    def __str__(self) -> str:  # matches the paper's rendering, e.g. "i16"
        return self.name


_REGISTRY = {
    "i8": Dtype("i8", 8, "int"),
    "u8": Dtype("u8", 8, "uint"),
    "i16": Dtype("i16", 16, "int"),
    "u16": Dtype("u16", 16, "uint"),
    "i32": Dtype("i32", 32, "int"),
    "u32": Dtype("u32", 32, "uint"),
    "f16": Dtype("f16", 16, "float"),
    "bf16": Dtype("bf16", 16, "float"),
    "f32": Dtype("f32", 32, "float"),
}


def dt(name: str) -> Dtype:
    """Look up a dtype by its paper-style name (``"i16"``, ``"bf16"`` ...)."""
    try:
        return _REGISTRY[name]
    except KeyError as e:
        raise KeyError(f"unknown dtype {name!r}; known: {sorted(_REGISTRY)}") from e


ALL_DTYPES = tuple(_REGISTRY.values())
