"""End-to-end driver: train a ~100M-param qwen3-family model on the
synthetic LM stream for a few hundred steps with the full production
stack (sharded step, checkpoints, fault tolerance).

Layer compilation runs through the unified driver first: the step's GEMMs
are compiled with ``repro.compile`` (``repro/launch/layers.py``) and the
accelerator cycle report printed; with ``REPRO_CACHE_DIR`` set, relaunches
replay the compiles from the disk artifact store.

    PYTHONPATH=src python examples/train_lm.py [--steps 300]
"""
import argparse

import jax

from repro import configs
from repro.data import SyntheticLM
from repro.launch.layers import layer_report
from repro.launch.mesh import make_host_mesh, use_mesh
from repro.models import get_model
from repro.optim import adamw, cosine_schedule
from repro.runtime import make_train_step, train_loop


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="checkpoints/train_lm")
    ap.add_argument("--accel-target", default="hvx")
    args = ap.parse_args()

    # ~100M params: qwen3 family, scaled width/depth
    cfg = configs.get_config("qwen3-0.6b").replace(
        n_layers=10, d_model=768, n_heads=12, n_kv_heads=6, d_ff=3072,
        vocab=32768, head_dim=64, param_dtype="float32",
        compute_dtype="float32", remat=False)
    model = get_model(cfg)
    from repro.roofline import param_count
    total, _ = param_count(cfg)
    print(f"[train_lm] {total / 1e6:.1f}M params")
    # per-GEMM accelerator cycles at the training token count (8 x 256),
    # compiled through the driver's pipeline/cache/store seam
    print(layer_report(cfg, tokens=8 * 256, target=args.accel_target))

    mesh = make_host_mesh()
    with use_mesh(mesh):
        params = model.init_params(jax.random.PRNGKey(0))
        opt = adamw(cosine_schedule(1e-3, 30, args.steps))
        opt_state = opt.init(params)
        step = jax.jit(make_train_step(model.loss_fn, opt, microbatches=2),
                       donate_argnums=(0, 1))
        data = SyntheticLM(vocab=cfg.vocab, seq_len=256, global_batch=8,
                           seed=0)
        params, opt_state, rep = train_loop(
            step, params, opt_state, lambda s: data.batch(s),
            steps=args.steps, ckpt_dir=args.ckpt_dir, ckpt_every=100,
            log_every=25)
    print(f"[train_lm] loss {rep.losses[0]:.3f} -> {rep.losses[-1]:.3f}")
    assert rep.losses[-1] < rep.losses[0]


if __name__ == "__main__":
    main()
