"""Distributed design-space sweep: shard (paper layers x derived PE-array
variants) across worker processes over a shared artifact store, then read
the best-variant-per-layer table off the merged ``SweepReport``.

    PYTHONPATH=src python examples/sweep_variants.py
    PYTHONPATH=src python examples/sweep_variants.py --workers 4 \
        --store /tmp/covenant-store

A second run against the same store deduplicates every work unit — the
coordinator reports them straight from the stored entries without
dispatching a single worker (watch the ``dedup`` counts and the
``0 pipeline stages run`` summary).  The same sweep is scriptable as
``python -m repro.sweep`` (that is what the CI ``sweep-parallel`` job
runs) and, claim-file-coordinated, as a fleet of independently launched
``--external`` workers.
"""
import argparse
import tempfile

import repro

LAYERS = ["DLRM-FC1", "DLRM-FC2", "DLRM-FC3", "DLRM-FC4",
          "BERT-LG-GEMM1", "BERT-LG-GEMM2"]
VARIANTS = ["dnnweaver", "dnnweaver@pe=32x32", "dnnweaver@pe=16x16",
            "hvx", "hvx@issue_slots=8"]


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--workers", type=int, default=2)
    ap.add_argument("--store", default=None)
    args = ap.parse_args()
    store = args.store or tempfile.mkdtemp(prefix="covenant-store-")

    for run in ("cold", "warm"):
        report = repro.sweep(LAYERS, VARIANTS, workers=args.workers,
                             store=store)
        print(f"[{run}] {report.summary()}")
    print()
    print(report.best_table())
    journal = repro.ArtifactStore(store).journal(report.sweep_id)
    counts = journal.compile_counts()
    assert set(counts.values()) == {1}, counts  # each unit compiled once
    print(f"\njournal: {len(counts)} work units, each compiled exactly "
          f"once across both runs (store: {store})")


if __name__ == "__main__":
    main()
