"""Batched serving example: continuous-batching decode loop against a
smoke-size gemma3 (sliding-window KV caches exercised).

Layer compilation is migrated onto the unified driver: the serving stack
compiles the model's decode-shape GEMMs with ``repro.compile`` (see
``repro/launch/layers.py``) and prints the accelerator cycle report before
serving.  Set ``REPRO_CACHE_DIR`` to replay those compiles from the disk
artifact store on relaunch.

    PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma3-12b",
         "--smoke", "--requests", "8", "--batch", "4", "--max-new", "16",
         "--accel-target", "hvx"]))
