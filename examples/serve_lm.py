"""Batched serving example: continuous-batching decode loop against a
smoke-size gemma3 (sliding-window KV caches exercised).

    PYTHONPATH=src python examples/serve_lm.py
"""
import subprocess
import sys

if __name__ == "__main__":
    sys.exit(subprocess.call(
        [sys.executable, "-m", "repro.launch.serve", "--arch", "gemma3-12b",
         "--smoke", "--requests", "8", "--batch", "4", "--max-new", "16"]))
