"""Quickstart: the paper's pipeline end-to-end on two targets, via the
unified compile driver.

One ``repro.compile(codelet, target)`` call runs the whole Covenant flow
(placement -> compute mapping -> Algorithm-1 tiling -> transfer insertion ->
optimization passes) and returns a cached ``CompiledArtifact``; the
macro-mnemonic program, stream execution and analytic cycle count hang off
the artifact.  Retargeting is the ``target=`` argument — nothing else
changes.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

import repro
from repro.core import library


def main() -> None:
    rng = np.random.default_rng(0)
    cdlt = library.gemm(16, 32, 24, in_dtype="u8", acc_dtype="i32")
    A = rng.integers(0, 8, (16, 24)).astype(np.uint8)
    B = rng.integers(0, 8, (24, 32)).astype(np.uint8)
    want = (A.astype(np.int64) @ B.astype(np.int64)).astype(np.int32)

    for target in ("hvx", "dnnweaver"):
        art = repro.compile(cdlt, target)
        print(f"=== {target} ===")
        for note in art.schedule_notes:
            print("  ", note)
        prog = art.program
        print(f"   {len(prog)} mnemonics ({prog.bytes} bytes); first 5:")
        for line in art.listing(5).splitlines():
            print("    ", line)
        res = art.run({"A": A, "B": B})
        ok = np.array_equal(res.outputs["C"], want)
        print(f"   correct={ok} serial={res.serial_cycles:.0f}cyc "
              f"packed={res.packed_cycles:.0f}cyc "
              f"(analytic {art.cycles():.0f})")
        assert ok
        # a repeated compile of the same (codelet, target, options) is served
        # from the content-addressed cache: the very same artifact comes back
        assert repro.compile(cdlt, target) is art

    # targets are addressable by name everywhere — including *derived
    # variants*: the registry parses "base@key=value" and derives the
    # covenant spec on the fly (the paper's adaptability claim, one string)
    half = repro.compile("DLRM-FC1", "dnnweaver@pe=32x32")
    full = repro.compile("DLRM-FC1", "dnnweaver")
    print(f"=== DLRM-FC1 on dnnweaver@pe=32x32 ===\n   "
          f"{half.cycles():.0f} cyc vs {full.cycles():.0f} cyc on the "
          f"64x64 array (distinct store keys: {half.key != full.key})")

    stats = repro.cache_stats()
    print(f"compile cache: {stats['hits']} hits / {stats['misses']} misses")


if __name__ == "__main__":
    main()
