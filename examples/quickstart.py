"""Quickstart: the paper's pipeline end-to-end on two targets.

Defines an ``add`` Codelet (Fig 7), schedules it with the Covenant compiler
against the HVX and DNNWeaver ACGs (placement -> compute mapping ->
Algorithm-1 tiling -> transfer insertion -> optimization passes), generates
macro-mnemonic streams, executes them on the stream machine, and checks
the result against numpy.

    PYTHONPATH=src python examples/quickstart.py
"""
import numpy as np

from repro.core import codegen, cost, library, scheduler, stream, targets


def main() -> None:
    rng = np.random.default_rng(0)
    cdlt = library.gemm(16, 32, 24, in_dtype="u8", acc_dtype="i32")
    A = rng.integers(0, 8, (16, 24)).astype(np.uint8)
    B = rng.integers(0, 8, (24, 32)).astype(np.uint8)
    want = (A.astype(np.int64) @ B.astype(np.int64)).astype(np.int32)

    for target in ("hvx", "dnnweaver"):
        acg = targets.get_target(target)
        sched = scheduler.schedule(cdlt, acg)
        print(f"=== {target} ===")
        for note in sched.schedule_notes:
            print("  ", note)
        prog = codegen.generate(sched, acg)
        print(f"   {len(prog)} mnemonics ({prog.bytes} bytes); first 5:")
        for line in prog.listing(5).splitlines():
            print("    ", line)
        res = stream.run_stream(prog, {"A": A, "B": B})
        ok = np.array_equal(res.outputs["C"], want)
        rep = cost.cost(sched, acg)
        print(f"   correct={ok} serial={res.serial_cycles:.0f}cyc "
              f"packed={res.packed_cycles:.0f}cyc "
              f"(analytic {rep.cycles:.0f})")
        assert ok


if __name__ == "__main__":
    main()
