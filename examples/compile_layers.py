"""Table-2 sweep: batch-compile all 17 paper layers for both targets with
``repro.compile_many`` and print the per-layer cycle summary (the data
behind Figs 11/13).  Artifacts are cached content-addressed, so re-running
a sweep (or overlapping one, e.g. the Fig-12 ablation) re-uses compiles.

Extra positional arguments are target names — any ``repro.targets`` name,
including derived variants — appended as columns:

    PYTHONPATH=src python examples/compile_layers.py
    PYTHONPATH=src python examples/compile_layers.py dnnweaver@pe=32x32
"""
import sys

import repro
from repro.core import library

OPT = repro.CompileOptions(vectorize=True, unroll=True, pack=True)
BASE = repro.CompileOptions(vectorize=False, unroll=False, pack=False)


def main(extra_targets: list[str] = ()) -> None:
    base_arts = repro.compile_many(library.PAPER_LAYERS, target="hvx",
                                   options=BASE)
    opt_arts = repro.compile_many(library.PAPER_LAYERS, target="hvx",
                                  options=OPT)
    dnnw_arts = repro.compile_many(library.PAPER_LAYERS, target="dnnweaver",
                                   options=OPT)
    # one heterogeneous batch covers every (layer, extra target) point
    extra = repro.compile_many(
        [(spec, t) for t in extra_targets for spec in library.PAPER_LAYERS],
        options=OPT)
    cols = "".join(f" {t[:20]:>20s}" for t in extra_targets)
    print(f"{'layer':22s} {'base(HVX)':>12s} {'opt(HVX)':>12s} "
          f"{'speedup':>8s} {'opt(DNNW)':>12s}{cols}")
    n = len(library.PAPER_LAYERS)
    for i, (spec, b, o, d) in enumerate(zip(library.PAPER_LAYERS, base_arts,
                                            opt_arts, dnnw_arts)):
        base, opt, dn = b.cycles(), o.cycles(), d.cycles()
        row = (f"{spec.key:22s} {base:12.0f} {opt:12.0f} {base / opt:8.1f} "
               f"{dn:12.0f}")
        for t in range(len(extra_targets)):
            row += f" {extra[t * n + i].cycles():20.0f}"
        print(row)


if __name__ == "__main__":
    main(sys.argv[1:])
