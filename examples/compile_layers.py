"""Table-2 sweep: compile all 17 paper layers for both targets and print
the per-layer cycle summary (the data behind Figs 11/13).

    PYTHONPATH=src python examples/compile_layers.py
"""
from repro.core import cost, library, scheduler, targets
from repro.core.scheduler import ScheduleConfig

OPT = ScheduleConfig(vectorize=True, unroll=True, pack=True)
BASE = ScheduleConfig(vectorize=False, unroll=False, pack=False)


def main() -> None:
    hvx = targets.get_target("hvx")
    dnnw = targets.get_target("dnnweaver")
    print(f"{'layer':22s} {'base(HVX)':>12s} {'opt(HVX)':>12s} "
          f"{'speedup':>8s} {'opt(DNNW)':>12s}")
    for spec in library.PAPER_LAYERS:
        base = cost.cost(scheduler.schedule(spec.build(), hvx, BASE), hvx,
                         pack=False).cycles
        opt = cost.cost(scheduler.schedule(spec.build(), hvx, OPT), hvx).cycles
        dn = cost.cost(scheduler.schedule(spec.build(), dnnw, OPT),
                       dnnw).cycles
        print(f"{spec.key:22s} {base:12.0f} {opt:12.0f} {base / opt:8.1f} "
              f"{dn:12.0f}")


if __name__ == "__main__":
    main()
