"""Table-2 sweep: batch-compile all 17 paper layers for both targets with
``repro.compile_many`` and print the per-layer cycle summary (the data
behind Figs 11/13).  Artifacts are cached content-addressed, so re-running
a sweep (or overlapping one, e.g. the Fig-12 ablation) re-uses compiles.

    PYTHONPATH=src python examples/compile_layers.py
"""
import repro
from repro.core import library

OPT = repro.CompileOptions(vectorize=True, unroll=True, pack=True)
BASE = repro.CompileOptions(vectorize=False, unroll=False, pack=False)


def main() -> None:
    base_arts = repro.compile_many(library.PAPER_LAYERS, target="hvx",
                                   options=BASE)
    opt_arts = repro.compile_many(library.PAPER_LAYERS, target="hvx",
                                  options=OPT)
    dnnw_arts = repro.compile_many(library.PAPER_LAYERS, target="dnnweaver",
                                   options=OPT)
    print(f"{'layer':22s} {'base(HVX)':>12s} {'opt(HVX)':>12s} "
          f"{'speedup':>8s} {'opt(DNNW)':>12s}")
    for spec, b, o, d in zip(library.PAPER_LAYERS, base_arts, opt_arts,
                             dnnw_arts):
        base, opt, dn = b.cycles(), o.cycles(), d.cycles()
        print(f"{spec.key:22s} {base:12.0f} {opt:12.0f} {base / opt:8.1f} "
              f"{dn:12.0f}")


if __name__ == "__main__":
    main()
