"""Defining a brand-new accelerator purely as a covenant spec.

The paper's adaptability claim: the ACG lets a compiler absorb accelerator
design changes "without complete compiler redevelopment".  This example
makes that claim concrete — it declares a new edge-NPU-style target as
*data* (an ``ACGSpec``: memories, capabilities, edges; the mnemonic
vocabulary is generated), registers it by name, and compiles every paper
layer through the unchanged driver.  Zero edits to ``repro/core``.

It then derives a scaled family member (quarter-size PE array) with
``spec.derive`` and shows the two variants get distinct store keys and
distinct cost reports — the paper's design-space sweep as three lines of
code.

    PYTHONPATH=src python examples/new_accelerator.py
"""
import repro
from repro.core import library
from repro.core.spec import acg_spec, scap, scu, sedge, smem, sop

# A 16x16 weight-stationary NPU: DRAM-backed, one unified scratchpad (SPM)
# feeding a 16x16 int8 systolic array and a 16-lane vector unit.
EDGE_NPU = acg_spec(
    "edge_npu",
    memories=[
        smem("DRAM", data_width=8, banks=1, depth=1 << 30, offchip=True),
        smem("SPM", data_width=32, banks=64, depth=8192),   # 2 MiB
    ],
    computes=[
        scu("PEGRID", [
            scap("GEMM", sop("i32", 16),
                 [sop("i8", 16), sop("i8", 16, 16), sop("i32", 16)],
                 geometry=(1, 16, 16)),
            scap("MAC", sop("i32", 16),
                 [sop("i8", 16), sop("i8", 16, 16), sop("i32", 16)],
                 geometry=(1, 16, 16)),
        ], slot="grid"),
        scu("VLANES", [
            *(scap(n, sop("i32", 16), [sop("i32", 16)] * 2)
              for n in ("ADD", "SUB", "MUL", "MAX", "MIN")),
            *(scap(n, sop("i32", 16), [sop("i32", 16)])
              for n in ("RELU", "SIGMOID", "TANH")),
        ], slot="vector"),
    ],
    edges=[
        sedge("DRAM", "SPM", bandwidth=128, bidir=True),
        sedge("SPM", "PEGRID", bandwidth=32 * 16, bidir=True),
        sedge("SPM", "VLANES", bandwidth=32 * 16, bidir=True),
    ],
    loop_overhead=0,   # hardware loop sequencer
    addr_bits=24,
)


def main() -> None:
    repro.validate_spec(EDGE_NPU)            # structural soundness up front
    repro.targets.register(EDGE_NPU)         # addressable by name everywhere
    print(f"registered {EDGE_NPU.name!r} "
          f"(fingerprint {EDGE_NPU.fingerprint()[:12]}); "
          f"targets: {repro.targets.list()}")

    arts = repro.compile_many(library.PAPER_LAYERS, target="edge_npu")
    print(f"\n{'layer':22s} {'edge_npu':>12s} {'@pe=8x8':>12s}")
    small = repro.compile_many(library.PAPER_LAYERS, target="edge_npu@pe=8x8")
    for spec, a, s in zip(library.PAPER_LAYERS, arts, small):
        print(f"{spec.key:22s} {a.cycles():12.0f} {s.cycles():12.0f}")
        assert a.key != s.key, "derived variant must key separately"

    # the derived family member is just data, too
    variant = EDGE_NPU.derive(pe="8x8")
    print(f"\nderived {variant.name!r}: "
          f"fingerprint {variant.fingerprint()[:12]} "
          f"(base {EDGE_NPU.fingerprint()[:12]})")
    stats = repro.cache_stats()
    print(f"compile cache: {stats['hits']} hits / {stats['misses']} misses")


if __name__ == "__main__":
    main()
