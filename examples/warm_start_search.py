"""Cost-model-guided search depth: beam vs evolutionary, strategy racing,
and cross-layer warm-starting from the artifact store.

    PYTHONPATH=src python examples/warm_start_search.py
    PYTHONPATH=src python examples/warm_start_search.py --store /tmp/ws

Three acts:

1. **Budget-matched race** — ``repro.sweep(..., searches=[beam, evo],
   race=True)`` runs both strategies per layer under one evaluation
   budget and *pins* each winner in the store (``report.race_table()``).
2. **Warm-started search** — a later search of a same-shaped layer seeds
   its population from the store's best recorded points
   (``SearchOptions(warm_start=True)`` via the ``WarmStartIndex`` built
   from the sweep journal + pins) and converges in fewer evaluations.
3. The winning schedules persist content-addressed: re-running this
   script against the same ``--store`` recompiles nothing.
"""
import argparse
import dataclasses
import tempfile

import repro

LAYERS = ["DLRM-FC1", "DLRM-FC2", "DLRM-FC3"]
BUDGET = dict(generations=4, population=10, seed=0, max_candidates=512)


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--store", default=None)
    ap.add_argument("--target", default="hvx")
    args = ap.parse_args()
    store = args.store or tempfile.mkdtemp(prefix="covenant-warm-")

    # -- act 1: race beam vs evolutionary, pin winners ----------------------
    searches = [repro.SearchOptions(strategy="beam", **BUDGET),
                repro.SearchOptions(strategy="evolutionary", **BUDGET)]
    report = repro.sweep(LAYERS, [args.target], store=store,
                         searches=searches, race=True)
    print(report.summary())
    print()
    print(report.race_table())

    # -- act 2: warm-start a fresh search from the recorded points ----------
    print("\nwarm-starting InceptionV3-FC1 (same GEMM shape family):")
    base = repro.SearchOptions(strategy="evolutionary", generations=10,
                               population=10, seed=3, max_candidates=512,
                               patience=2)
    for warm in (False, True):
        repro.clear_cache()  # make both runs search, not cache-hit
        sopts = dataclasses.replace(base, warm_start=warm)
        art = repro.compile("InceptionV3-FC1", args.target,
                            repro.CompileOptions(search=sopts, store=store))
        s = art.search
        print(f"  warm_start={warm!s:5s} -> {s.best_cycles:10.0f} cycles, "
              f"{s.evaluated:3d} evaluations, {len(s.trace)} generations, "
              f"{s.seeded} seed(s) injected")

    idx = repro.WarmStartIndex.from_store(repro.ArtifactStore(store))
    print(f"\nwarm-start index: {len(idx)} recorded points "
          f"(store: {store})")


if __name__ == "__main__":
    main()
